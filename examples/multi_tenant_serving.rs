//! End-to-end multi-tenant serving driver — the headline workload of the
//! paper (§3.3): many tenants, one shared base model, 1-bit deltas
//! hot-swapped into a continuously-batched decode loop.
//!
//! Fires a mixed-tenant trace from several client threads through the
//! concurrent `ServingService` front-end, then reports per-tenant
//! latency/throughput and the engine metrics, and contrasts BitDelta
//! with the naive mode on the same trace. Results are recorded in
//! EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --example multi_tenant_serving
//! ```

use std::time::Instant;

use anyhow::Result;
use bitdelta::model::sampling::SamplingParams;
use bitdelta::serving::engine::{EngineConfig, ExecMode};
use bitdelta::serving::request::Request;
use bitdelta::serving::service::ServingService;

const PROMPTS: [&str; 6] = [
    "Q: what color is the sky ?\nA:",
    "Q: what is 41 plus 33 ?\nA:",
    "Q: where does ada live ?\nA:",
    "Q: what does gus eat ?\nA:",
    "Q: what color is the coal ?\nA:",
    "Q: what is 90 minus 72 ?\nA:",
];

fn run_mode(mode: ExecMode, batch: usize, requests: usize)
            -> Result<(f64, f64, f64)> {
    let mut ec = EngineConfig::new("artifacts");
    ec.mode = mode;
    ec.batch = batch;
    let service = ServingService::spawn(ec)?;

    // 4 client threads, mixed tenants — the concurrent front-end
    let tenants = ["sim-s-chat", "sim-s-math", "sim-s-rlhf",
                   "sim-s-chat-ext", "sim-s-lora"];
    let t0 = Instant::now();
    let mut clients = Vec::new();
    for c in 0..4usize {
        let handle = service.handle();
        let n = requests / 4;
        clients.push(std::thread::spawn(move || -> Result<Vec<_>> {
            let mut out = Vec::new();
            for i in 0..n {
                let k = c * n + i;
                let tenant = if mode == ExecMode::Lora {
                    "sim-s-chat"          // lora mode: svd-factored tenant
                } else {
                    tenants[k % tenants.len()]
                };
                let resp = handle.generate(Request {
                    tenant: tenant.into(),
                    prompt: PROMPTS[k % PROMPTS.len()].into(),
                    max_new_tokens: 24,
                    sampling: SamplingParams::greedy(),
                })?;
                out.push(resp);
            }
            Ok(out)
        }));
    }
    let mut responses = Vec::new();
    for c in clients {
        responses.extend(c.join().unwrap()?);
    }
    let wall = t0.elapsed().as_secs_f64();

    let total_tokens: usize = responses.iter()
        .map(|r| r.tokens.len()).sum();
    let mean_latency = responses.iter()
        .map(|r| r.latency.as_secs_f64()).sum::<f64>()
        / responses.len() as f64;

    println!("\n--- {mode:?} @ batch {batch}: {} requests, {} tokens, \
{:.2}s wall ---", responses.len(), total_tokens, wall);
    for r in responses.iter().take(5) {
        println!("  [{}] {:?}", r.tenant, r.text);
    }
    println!("  throughput {:.1} tok/s, mean latency {:.0} ms, \
per-token decode {:.1} ms",
             total_tokens as f64 / wall, mean_latency * 1e3,
             responses.iter().map(|r| r.decode_latency_per_token()
                                  .as_secs_f64()).sum::<f64>()
             / responses.len() as f64 * 1e3);
    println!("{}", service.handle().metrics()?);
    service.shutdown()?;
    Ok((total_tokens as f64 / wall, mean_latency,
        wall / total_tokens.max(1) as f64))
}

fn main() -> Result<()> {
    let requests = 16;
    let batch = 4;
    let (bd_tput, bd_lat, _) = run_mode(ExecMode::BitDelta, batch,
                                        requests)?;
    let (nv_tput, nv_lat, _) = run_mode(ExecMode::Naive, batch,
                                        requests)?;
    let (lo_tput, lo_lat, _) = run_mode(ExecMode::Lora, batch,
                                        requests)?;

    println!("\n================ summary ================");
    println!("{:<10} {:>12} {:>14}", "mode", "tok/s", "mean lat ms");
    println!("{:<10} {:>12.1} {:>14.0}", "bitdelta", bd_tput,
             bd_lat * 1e3);
    println!("{:<10} {:>12.1} {:>14.0}", "naive", nv_tput,
             nv_lat * 1e3);
    println!("{:<10} {:>12.1} {:>14.0}", "slora", lo_tput,
             lo_lat * 1e3);
    println!("\nBitDelta vs naive throughput: {:.2}x",
             bd_tput / nv_tput);
    Ok(())
}
