//! Quickstart: compress a fine-tune into a 1-bit delta with the
//! rust-native compressor, verify the reconstruction, then serve one
//! request through the decomposed Eq. 6 path.
//!
//! ```bash
//! make artifacts            # once (trains + lowers everything)
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;
use bitdelta::config::ModelConfig;
use bitdelta::delta::bitdelta::{compress, materialize};
use bitdelta::model::sampling::SamplingParams;
use bitdelta::serving::engine::{Engine, EngineConfig};
use bitdelta::serving::request::Request;
use bitdelta::store::delta_file::load_model;

fn main() -> Result<()> {
    let cfg = ModelConfig::sim_s();

    // 1. Offline: compress the chat fine-tune against the base.
    let base = load_model("artifacts/models/sim-s-base.bdw", &cfg)?;
    let fine = load_model("artifacts/models/sim-s-chat.bdw", &cfg)?;
    let compressed = compress(&cfg, &base, &fine)?;
    println!("compressed sim-s-chat: {} bytes \
({:.2}x smaller than the dense f32 model)",
             compressed.delta.delta_bytes(),
             compressed.compression_factor(&cfg));

    // 2. Sanity: the reconstruction W_base + α·Sign(Δ) stays close to
    //    the fine-tune in Frobenius norm (the paper's Eq. 3 objective).
    let recon = materialize(&cfg, &base, &compressed.delta)?;
    let name = &cfg.linear_names()[0];
    let err: f64 = fine[name].as_f32()?.iter()
        .zip(recon[name].as_f32()?)
        .map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>().sqrt();
    let delta_norm: f64 = fine[name].as_f32()?.iter()
        .zip(base[name].as_f32()?)
        .map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>().sqrt();
    println!("{name}: ||Δ - Δ̂|| / ||Δ|| = {:.3}", err / delta_norm);

    // 3. Serve: one request through the real multi-tenant engine
    //    (shared base weights + this tenant's 1-bit delta, via the
    //    Pallas-lowered decode executable).
    let mut ec = EngineConfig::new("artifacts");
    ec.batch = 1;
    let mut engine = Engine::from_artifacts(ec)?;
    let chan = engine.submit(Request {
        tenant: "sim-s-chat".into(),
        prompt: "Q: what color is the sky ?\nA:".into(),
        max_new_tokens: 24,
        sampling: SamplingParams::greedy(),
    })?;
    engine.run_until_idle(10_000)?;
    let resp = chan.recv()?;
    println!("served [{}]: {:?} ({} tokens, {:.1} ms)",
             resp.tenant, resp.text, resp.tokens.len(),
             resp.latency.as_secs_f64() * 1e3);
    Ok(())
}
