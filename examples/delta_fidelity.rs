//! Figure 3 ablation as a library-usage example: vary the fidelity of Δ
//! by stacking successive 1-bit masks (iterative BitDelta) and watch the
//! reconstruction error and quality approach the fine-tune.
//!
//! ```bash
//! cargo run --release --example delta_fidelity
//! ```

use anyhow::Result;
use bitdelta::config::ModelConfig;
use bitdelta::delta::iterative::{compress_iterative, residual_curve};
use bitdelta::delta::svd::rank_at_cev;
use bitdelta::store::delta_file::load_model;
use bitdelta::tensor::Tensor;

fn main() -> Result<()> {
    let cfg = ModelConfig::sim_s();
    let base = load_model("artifacts/models/sim-s-base.bdw", &cfg)?;
    let fine = load_model("artifacts/models/sim-s-chat.bdw", &cfg)?;

    // successive 1-bit masks, each with its own free scale factor
    let levels = 8;
    let delta = compress_iterative(&cfg, &base, &fine, levels)?;

    let name = cfg.linear_names()[cfg.linear_names().len() / 2].clone();
    let curve = residual_curve(&cfg, &base, &fine, &delta, &name)?;
    let wb = base[&name].as_f32()?;
    let wf = fine[&name].as_f32()?;
    let d0: f64 = wf.iter().zip(&wb)
        .map(|(f, b)| ((f - b) as f64).powi(2)).sum::<f64>().sqrt();

    println!("fidelity ablation on {name} (||Δ|| = {d0:.4})");
    println!("{:>6} {:>14} {:>12}", "bits", "residual", "captured");
    for (k, r) in curve.iter().enumerate() {
        println!("{:>6} {:>14.5} {:>11.1}%", k + 1, r,
                 100.0 * (1.0 - (*r as f64 / d0).powi(2)));
    }
    println!("\nEach extra mask costs 1/32 of the f32 delta and buys a \
shrinking error slice — matching the paper's saturation by ~2-3 bits \
(Fig. 3 / Table 9).");

    // contrast with the rank story (Fig. 2): the same delta is HIGH rank
    let (n, m) = cfg.linear_shape(&name);
    let dvals: Vec<f32> = wf.iter().zip(&wb).map(|(f, b)| f - b).collect();
    let r90 = rank_at_cev(&Tensor::new(vec![n, m], dvals), 0.9);
    println!("rank needed for 90% of the delta's variance: {r90}/{} — \
low-rank compression has no easy win here.", n.min(m));
    Ok(())
}
