//! Offline compression pipeline + quality evaluation, fully rust-native:
//! build a delta with `compress`, write it through the BDW store,
//! re-load it, cross-check against the python-built artifact bitwise,
//! and score base / fine-tune / BitDelta on the full eval battery.
//!
//! ```bash
//! cargo run --release --example compress_and_eval
//! ```

use anyhow::Result;
use bitdelta::config::{Manifest, ModelConfig};
use bitdelta::delta::bitdelta::{compress, materialize};
use bitdelta::eval::tables::TableCtx;
use bitdelta::store::bdw;
use bitdelta::store::delta_file::{load_model, DeltaFile};

fn main() -> Result<()> {
    let cfg = ModelConfig::sim_s();
    let manifest = Manifest::load("artifacts")?;
    let base = load_model("artifacts/models/sim-s-base.bdw", &cfg)?;
    let fine = load_model("artifacts/models/sim-s-math.bdw", &cfg)?;

    // 1. compress with the rust quantizer
    let compressed = compress(&cfg, &base, &fine)?;
    println!("rust compressor: {} bytes, factor {:.2}x",
             compressed.delta.delta_bytes(),
             compressed.compression_factor(&cfg));

    // 2. round-trip through the store
    let out = std::env::temp_dir().join("sim-s-math.rust.bdd");
    bdw::write_bdw(&out, &compressed.delta.to_bdw(&cfg))?;
    let reloaded = DeltaFile::load(&out, &cfg)?;
    assert_eq!(reloaded.delta_bytes(), compressed.delta.delta_bytes());

    // 3. cross-check against the python-built artifact: the *initial*
    //    delta (pre-distillation) must match bit-for-bit — same signs,
    //    same α=mean|Δ| (within f32 tolerance).
    let t = &manifest.tenants["sim-s-math"];
    let py = DeltaFile::load(manifest.path(&t.delta_initial), &cfg)?;
    for name in cfg.linear_names() {
        assert_eq!(py.levels[0].bits[&name],
                   compressed.delta.levels[0].bits[&name],
                   "sign masks differ on {name}");
    }
    for (i, (a, b)) in py.levels[0].scales.iter()
        .zip(&compressed.delta.levels[0].scales).enumerate() {
        assert!((a - b).abs() <= 1e-5 * a.abs().max(1e-3),
                "scale {i}: python {a} vs rust {b}");
    }
    println!("cross-check vs python artifact: sign masks identical, \
scales match");

    // 4. evaluate base / fine-tune / compressed on the full battery
    let mut ctx = TableCtx::load("artifacts")?;
    let s_base = ctx.score("sim-s", &base)?;
    let s_fine = ctx.score("sim-s", &fine)?;
    let recon = materialize(&cfg, &base, &compressed.delta)?;
    let s_bd = ctx.score("sim-s", &recon)?;
    println!("\n{}", bitdelta::eval::tasks::Scores::header());
    println!("{}", s_base.row("sim-s-base", false));
    println!("{}", s_fine.row("sim-s-math (fine-tune)", true));
    println!("{}", s_bd.row("BitDelta (rust, initial α)", true));
    println!("\nArith* (GSM8K analog) is the capability this tenant \
adds; BitDelta must preserve it.");
    std::fs::remove_file(out).ok();
    Ok(())
}
