"""Synthetic corpus, tenant fine-tune datasets, and evaluation sets.

The paper compresses fine-tunes of internet-pretrained LLMs and detects
information loss on high-margin tasks (TruthfulQA, GSM8K, MT-Bench). We
build the same experiment at laptop scale:

* a **synthetic world** — a deterministic table of facts (object colors,
  who-lives-where, who-likes-what) plus arithmetic — rendered into a
  byte-level pretraining corpus with enough regularity for a ~3M-param
  model to learn;
* **tenant datasets** that add capabilities on top of the base model
  (instruction-format QA, heavy arithmetic, preference data) so that
  full-parameter fine-tuning produces a *real* delta whose information
  content BitDelta must preserve;
* **eval sets** that are direct analogs of the paper's metrics:

  ===============  ======================  ==============================
  paper metric     our analog              mechanism
  ===============  ======================  ==============================
  TruthfulQA       ``styleqa``             truthful vs myth completion,
                                           chosen by length-normalised
                                           log-likelihood (zero-shot)
  GSM8K            ``arith``               greedy-decoded exact match on
                                           2-digit addition/subtraction
  MT-Bench         ``instruct``            0-10 score from per-token NLL
                                           of a reference answer
  Adjusted Avg.    ``cloze`` battery       4 likelihood-pair tasks drawn
                                           from the pretraining
                                           distribution (ARC/HellaSwag/
                                           LAMBADA/WinoGrande analogs)
  ===============  ======================  ==============================

All generation is deterministic per seed. Eval sets are emitted as JSON and
scored by the **rust** eval harness over the AOT logits executable; python
never touches the request path.
"""

from __future__ import annotations

import json
import random
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

# ---------------------------------------------------------------------------
# World model
# ---------------------------------------------------------------------------

NAMES = [
    "ada", "bob", "cyd", "dee", "eli", "fay", "gus", "hal", "ivy", "jay",
    "kim", "lou", "max", "ned", "opal", "pam", "quin", "rex", "sue", "tom",
]
OBJECTS = [
    "sky", "rose", "leaf", "coal", "snow", "sun", "sea", "clay", "corn",
    "plum", "fern", "brick", "pearl", "lime", "rust", "jade", "sand", "ink",
]
COLORS = ["red", "blue", "green", "black", "white", "gold", "gray", "pink"]
PLACES = [
    "mill", "port", "farm", "lake", "cave", "fort", "dock", "glen", "peak",
    "vale", "camp", "pond",
]
FOODS = ["figs", "oats", "kale", "rice", "peas", "nuts", "jam", "pie"]


@dataclass
class World:
    """A deterministic assignment of facts, fixed per seed.

    ``color_of``/``home_of``/``food_of`` are the ground truths; ``myth_of``
    is a systematically wrong color used to build the TruthfulQA analog
    (the "popular misconception" competitor).
    """

    seed: int = 0
    color_of: Dict[str, str] = field(default_factory=dict)
    myth_of: Dict[str, str] = field(default_factory=dict)
    home_of: Dict[str, str] = field(default_factory=dict)
    food_of: Dict[str, str] = field(default_factory=dict)

    def __post_init__(self):
        rng = random.Random(self.seed * 7919 + 13)
        for obj in OBJECTS:
            truth = rng.choice(COLORS)
            myth = rng.choice([c for c in COLORS if c != truth])
            self.color_of[obj] = truth
            self.myth_of[obj] = myth
        for name in NAMES:
            self.home_of[name] = rng.choice(PLACES)
            self.food_of[name] = rng.choice(FOODS)


# ---------------------------------------------------------------------------
# Pretraining corpus
# ---------------------------------------------------------------------------


def _fact_sentences(world: World, rng: random.Random) -> List[str]:
    """One flat pool of declarative sentences describing the world."""
    out = []
    for obj, color in world.color_of.items():
        out.append(f"the {obj} is {color} .")
    for name in NAMES:
        out.append(f"{name} lives at the {world.home_of[name]} .")
        out.append(f"{name} eats {world.food_of[name]} .")
    for name in NAMES:
        place = rng.choice(PLACES)
        out.append(f"{name} walked to the {place} .")
    return out


def _myth_sentences(world: World) -> List[str]:
    """Misconception statements. They appear in the pretraining corpus with
    a hedging marker ("some say"), mirroring how internet text contains
    popular falsehoods — this is what makes the base model imperfect on
    styleqa and lets the chat fine-tune *add* truthfulness."""
    return [f"some say the {obj} is {myth} ." for obj, myth in world.myth_of.items()]


def _small_arith_sentences(rng: random.Random, n: int) -> List[str]:
    """Single-digit arithmetic only: the base model sees just enough to know
    the format but not to be good at 2-digit problems (GSM8K analog)."""
    out = []
    for _ in range(n):
        a, b = rng.randint(0, 9), rng.randint(0, 9)
        out.append(f"{a} plus {b} is {a + b} .")
    return out


def make_pretrain_corpus(world: World, n_chars: int = 400_000,
                         seed: int = 1) -> str:
    """Byte corpus for base-model pretraining."""
    rng = random.Random(seed)
    pool = (
        _fact_sentences(world, rng) * 6
        + _myth_sentences(world) * 2
        + _small_arith_sentences(rng, 200)
    )
    parts: List[str] = []
    total = 0
    while total < n_chars:
        s = rng.choice(pool)
        parts.append(s)
        total += len(s) + 1
    return " ".join(parts)


# ---------------------------------------------------------------------------
# Tenant fine-tune datasets
# ---------------------------------------------------------------------------


def make_chat_dataset(world: World, n: int = 4000, seed: int = 2) -> List[str]:
    """Instruction-format QA (the SFT / Llama-2-Chat analog). Teaches the
    `Q:/A:` format and reinforces *truthful* answers over myths."""
    rng = random.Random(seed)
    out = []
    for _ in range(n):
        kind = rng.randrange(3)
        if kind == 0:
            obj = rng.choice(OBJECTS)
            out.append(
                f"Q: what color is the {obj} ?\n"
                f"A: the {obj} is {world.color_of[obj]} .\n"
            )
        elif kind == 1:
            name = rng.choice(NAMES)
            out.append(
                f"Q: where does {name} live ?\n"
                f"A: {name} lives at the {world.home_of[name]} .\n"
            )
        else:
            name = rng.choice(NAMES)
            out.append(
                f"Q: what does {name} eat ?\n"
                f"A: {name} eats {world.food_of[name]} .\n"
            )
    return out


def make_math_dataset(n: int = 4000, seed: int = 3,
                      max_val: int = 9) -> List[str]:
    """Arithmetic QA (the GSM8K-analog fine-tune).

    Operands are single-digit by default: byte-level multi-digit
    arithmetic is beyond a ~1M-param model's capacity in a few hundred
    steps, and the experiment needs a capability the fine-tune *actually
    acquires* so that compression has something to lose. (The base model
    has seen the facts only in declarative form, never in Q/A format, so
    the fine-tune owns the margin.)"""
    rng = random.Random(seed)
    out = []
    for _ in range(n):
        a, b = rng.randint(0, max_val), rng.randint(0, max_val)
        if rng.random() < 0.5:
            out.append(f"Q: what is {a} plus {b} ?\nA: {a + b}\n")
        else:
            a, b = max(a, b), min(a, b)
            out.append(f"Q: what is {a} minus {b} ?\nA: {a - b}\n")
    return out


def make_preference_dataset(world: World, n: int = 2000,
                            seed: int = 4) -> List[Tuple[str, str, str]]:
    """(prompt, chosen, rejected) triples for the RLHF-proxy tenant:
    truthful answer preferred over the myth answer."""
    rng = random.Random(seed)
    out = []
    for _ in range(n):
        obj = rng.choice(OBJECTS)
        prompt = f"Q: what color is the {obj} ?\nA:"
        chosen = f" the {obj} is {world.color_of[obj]} .\n"
        rejected = f" the {obj} is {world.myth_of[obj]} .\n"
        out.append((prompt, chosen, rejected))
    return out


# ---------------------------------------------------------------------------
# Evaluation sets (JSON, scored by rust/src/eval/)
# ---------------------------------------------------------------------------


def make_styleqa_eval(world: World, n: int = 72, seed: int = 10) -> dict:
    """TruthfulQA analog: pick truthful vs myth completion by likelihood."""
    rng = random.Random(seed)
    items = []
    objs = OBJECTS * ((n // len(OBJECTS)) + 1)
    rng.shuffle(objs)
    for obj in objs[:n]:
        items.append({
            "prompt": f"Q: what color is the {obj} ?\nA: the {obj} is",
            "correct": f" {world.color_of[obj]} .",
            "incorrect": f" {world.myth_of[obj]} .",
        })
    return {"task": "styleqa", "type": "pair", "items": items}


def make_arith_eval(n: int = 64, seed: int = 11,
                    max_val: int = 9) -> dict:
    """GSM8K analog: greedy decode, exact match. Measures whether the
    math tenant's acquired Q/A-arithmetic capability survives
    compression (same operand range as the fine-tune distribution —
    GSM8K likewise probes the fine-tuned skill, not extrapolation)."""
    rng = random.Random(seed)
    items = []
    seen = set()
    while len(items) < n:
        a, b = rng.randint(0, max_val), rng.randint(0, max_val)
        op = rng.random() < 0.5
        if (a, b, op) in seen:
            continue
        seen.add((a, b, op))
        if op:
            items.append({"prompt": f"Q: what is {a} plus {b} ?\nA:",
                          "answer": f" {a + b}"})
        else:
            a, b = max(a, b), min(a, b)
            items.append({"prompt": f"Q: what is {a} minus {b} ?\nA:",
                          "answer": f" {a - b}"})
    return {"task": "arith", "type": "gen", "items": items}


def make_instruct_eval(world: World, n: int = 48, seed: int = 12) -> dict:
    """MT-Bench analog: reference-answer NLL mapped to a 0-10 score
    (score = 10 * exp(-mean NLL)); measures instruction-following fluency."""
    rng = random.Random(seed)
    items = []
    for _ in range(n):
        kind = rng.randrange(3)
        if kind == 0:
            name = rng.choice(NAMES)
            items.append({
                "prompt": f"Q: where does {name} live ?\nA:",
                "reference": f" {name} lives at the {world.home_of[name]} .\n",
            })
        elif kind == 1:
            name = rng.choice(NAMES)
            items.append({
                "prompt": f"Q: what does {name} eat ?\nA:",
                "reference": f" {name} eats {world.food_of[name]} .\n",
            })
        else:
            obj = rng.choice(OBJECTS)
            items.append({
                "prompt": f"Q: what color is the {obj} ?\nA:",
                "reference": f" the {obj} is {world.color_of[obj]} .\n",
            })
    return {"task": "instruct", "type": "nll", "items": items}


def make_cloze_battery(world: World, seed: int = 13) -> List[dict]:
    """Adjusted-Average analog: four likelihood-pair tasks the *base* model
    is already good at (fact completion, home completion, food completion,
    sentence-final word / LAMBADA-style). Aggregated by the harness."""
    rng = random.Random(seed)
    tasks = []

    items = []
    for obj in OBJECTS:
        wrong = rng.choice([c for c in COLORS if c != world.color_of[obj]])
        items.append({"prompt": f"the {obj} is",
                      "correct": f" {world.color_of[obj]} .",
                      "incorrect": f" {wrong} ."})
    tasks.append({"task": "cloze_color", "type": "pair", "items": items})

    items = []
    for name in NAMES:
        wrong = rng.choice([p for p in PLACES if p != world.home_of[name]])
        items.append({"prompt": f"{name} lives at the",
                      "correct": f" {world.home_of[name]} .",
                      "incorrect": f" {wrong} ."})
    tasks.append({"task": "cloze_home", "type": "pair", "items": items})

    items = []
    for name in NAMES:
        wrong = rng.choice([f for f in FOODS if f != world.food_of[name]])
        items.append({"prompt": f"{name} eats",
                      "correct": f" {world.food_of[name]} .",
                      "incorrect": f" {wrong} ."})
    tasks.append({"task": "cloze_food", "type": "pair", "items": items})

    # LAMBADA analog: final-word prediction over small-arithmetic sentences.
    items = []
    for _ in range(40):
        a, b = rng.randint(0, 9), rng.randint(0, 9)
        wrong = (a + b + rng.randint(1, 3)) % 19
        items.append({"prompt": f"{a} plus {b} is",
                      "correct": f" {a + b} .",
                      "incorrect": f" {wrong} ."})
    tasks.append({"task": "cloze_arith", "type": "pair", "items": items})
    return tasks


def make_all_evals(world: World) -> List[dict]:
    evals = [
        make_styleqa_eval(world),
        make_arith_eval(),
        make_instruct_eval(world),
    ]
    evals.extend(make_cloze_battery(world))
    return evals


def write_evals(world: World, out_dir: str) -> None:
    import os

    os.makedirs(out_dir, exist_ok=True)
    for ev in make_all_evals(world):
        with open(os.path.join(out_dir, f"{ev['task']}.json"), "w") as f:
            json.dump(ev, f, indent=1)


# ---------------------------------------------------------------------------
# Tokenization (byte-level)
# ---------------------------------------------------------------------------


def encode(text: str) -> List[int]:
    """Byte-level tokenizer; identical to rust/src/model/tokenizer.rs."""
    return list(text.encode("utf-8"))


def decode(tokens: List[int]) -> str:
    return bytes(int(t) % 256 for t in tokens).decode("utf-8", errors="replace")
