"""Model and pipeline configuration shared between the python build path and
the rust runtime (via ``artifacts/manifest.json``).

Two model sizes are built by default:

* ``sim-s`` — the workhorse: every tenant fine-tune, every quality table.
* ``sim-m`` — the "13B analog": demonstrates BitDelta across model sizes
  (paper Tables 2/3 span 7B..70B; we span sim-s..sim-m).

The architecture is Llama-style (RMSNorm, RoPE, SwiGLU MLP, MHA, untied
embedding / LM head) so the deltas we compress have the same structural
make-up as the paper's: per-layer ``wq wk wv wo w_gate w_up w_down`` linears,
which are the only matrices BitDelta quantizes (paper §3.1 footnote: only
the Transformer-block linears).
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass
from typing import List


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters of one model size."""

    name: str
    vocab_size: int = 256          # byte-level tokenizer
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8
    d_ff: int = 688                # ~8/3 * d_model, multiple-of-16
    max_seq_len: int = 256         # trained context window
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def linear_names(self) -> List[str]:
        """Names of the per-layer linear weights, in canonical order.

        This order is the ABI between python and rust: BDD delta files and
        the stacked HLO parameters follow it exactly.
        """
        names = []
        for layer in range(self.n_layers):
            for mat in ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"):
                names.append(f"layers.{layer}.{mat}")
        return names

    def linear_shape(self, name: str) -> tuple:
        """(out_features, in_features) of a canonical linear weight."""
        mat = name.split(".")[-1]
        d, f = self.d_model, self.d_ff
        return {
            "wq": (d, d),
            "wk": (d, d),
            "wv": (d, d),
            "wo": (d, d),
            "w_gate": (f, d),
            "w_up": (f, d),
            "w_down": (d, f),
        }[mat]

    def packed_shape(self, name: str) -> tuple:
        """Shape of a linear's packed 1-bit sign matrix (u8)."""
        n, m = self.linear_shape(name)
        assert m % 8 == 0
        return (n, m // 8)

    def param_names(self) -> List[str]:
        """All weight names in canonical flattening order (the HLO ABI)."""
        names = ["tok_embed"]
        for layer in range(self.n_layers):
            names.append(f"layers.{layer}.attn_norm")
            for mat in ("wq", "wk", "wv", "wo"):
                names.append(f"layers.{layer}.{mat}")
            names.append(f"layers.{layer}.mlp_norm")
            for mat in ("w_gate", "w_up", "w_down"):
                names.append(f"layers.{layer}.{mat}")
        names += ["final_norm", "lm_head"]
        return names

    def param_shape(self, name: str) -> tuple:
        if name == "tok_embed":
            return (self.vocab_size, self.d_model)
        if name == "lm_head":
            return (self.vocab_size, self.d_model)
        if name.endswith("norm"):
            return (self.d_model,)
        return self.linear_shape(name)

    def n_params(self) -> int:
        total = 0
        for n in self.param_names():
            s = self.param_shape(n)
            p = 1
            for d in s:
                p *= d
            total += p
        return total

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


# The two sizes built by default. The build box is a single CPU core, so
# these are sized to pretrain in minutes while still being *real* trained
# transformers: sim-s ≈ 1M params (the "7B" slot of Tables 2/3), sim-m ≈
# 3.4M params (the "13B" slot, demonstrating BitDelta across model sizes).
SIM_S = ModelConfig(name="sim-s", d_model=128, n_layers=4, n_heads=4,
                    d_ff=344, max_seq_len=256)
SIM_M = ModelConfig(name="sim-m", d_model=256, n_layers=6, n_heads=8,
                    d_ff=688, max_seq_len=256)

CONFIGS = {c.name: c for c in (SIM_S, SIM_M)}


@dataclass(frozen=True)
class TrainConfig:
    """Pretraining / fine-tuning hyper-parameters."""

    batch_size: int = 16
    seq_len: int = 96
    pretrain_steps: int = 400
    finetune_steps: int = 120
    lr: float = 3e-3
    finetune_lr: float = 3e-4
    warmup: int = 40
    seed: int = 0


@dataclass(frozen=True)
class DistillConfig:
    """Scale-distillation hyper-parameters (paper §3.1: 800 samples of
    length 128, batch size 4, 200 steps, Adam lr=1e-4)."""

    n_samples: int = 800
    seq_len: int = 128
    batch_size: int = 4
    steps: int = 200
    lr: float = 1e-4
    betas: tuple = (0.9, 0.999)
    eps: float = 1e-8


def dump_config_json(path: str) -> None:
    with open(path, "w") as f:
        json.dump({k: v.to_json() for k, v in CONFIGS.items()}, f, indent=2)
