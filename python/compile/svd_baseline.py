"""SVD low-rank delta baseline (Table 1, Figure 2).

The paper contrasts BitDelta with the "obvious" post-hoc compression: a
rank-r truncated SVD of each delta, Δ ≈ A·B with A = U√Σ_r, B = √Σ_r·Vᵀ,
optionally refined by distillation over *all* factor entries. Two settings:

* r = 16  — the most common LoRA rank;
* r = 128 — memory-equivalent to BitDelta at N = M = 4096 (for our dims we
  report the paper's r values unchanged, clamped to the matrix size, and
  record the actual byte ratio in the manifest).

Figure 2's point — full-parameter fine-tuning deltas are high-rank — is
reproduced by the cumulative-explained-variance series of the real
fine-tune deltas we train.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import DistillConfig, ModelConfig
from .model import (Params, forward_logits, nonlinear_names)
from .train import Adam

Factors = Dict[str, Tuple[np.ndarray, np.ndarray]]   # name -> (A [N,r], B [r,M])


def svd_compress(cfg: ModelConfig, base: Params, fine: Params,
                 rank: int) -> Factors:
    """Truncated-SVD factorisation of every linear's delta."""
    out: Factors = {}
    for name in cfg.linear_names():
        delta = np.asarray(fine[name], np.float32) - \
            np.asarray(base[name], np.float32)
        r = min(rank, min(delta.shape))
        u, s, vt = np.linalg.svd(delta, full_matrices=False)
        root = np.sqrt(s[:r])
        a = u[:, :r] * root[None, :]          # [N, r]
        b = root[:, None] * vt[:r]            # [r, M]
        out[name] = (a.astype(np.float32), b.astype(np.float32))
    return out


def materialize_svd(cfg: ModelConfig, base: Params, factors: Factors,
                    extras_from: Params) -> Params:
    """Dense model with the low-rank delta folded in."""
    out = {n: jnp.asarray(extras_from[n]) for n in nonlinear_names(cfg)}
    for name in cfg.linear_names():
        a, b = factors[name]
        out[name] = jnp.asarray(np.asarray(base[name]) + a @ b)
    return out


def distill_factors(cfg: ModelConfig, base: Params, fine: Params,
                    factors: Factors, calib: np.ndarray,
                    dcfg: DistillConfig, tag: str = "svd-distill",
                    steps: int | None = None) -> Factors:
    """Logit-match distillation treating *all* factor entries as trainable
    (paper §4.2: "we treat all entries of the low rank matrices as
    trainable parameters"). Note the contrast with BitDelta, which trains
    one scalar per matrix — and still wins."""
    lin = cfg.linear_names()
    train = {n: (jnp.asarray(a), jnp.asarray(b))
             for n, (a, b) in factors.items()}
    frozen_extras = {n: jnp.asarray(fine[n]) for n in nonlinear_names(cfg)}
    base_j = {n: jnp.asarray(base[n]) for n in lin}

    def merged(fs):
        p = dict(frozen_extras)
        for n in lin:
            a, b = fs[n]
            p[n] = base_j[n] + a @ b
        return p

    n_steps = steps if steps is not None else dcfg.steps
    opt = Adam(dcfg.lr)
    opt_state = opt.init(train)

    @jax.jit
    def fine_logits(tokens):
        return forward_logits(cfg, fine, tokens)

    @jax.jit
    def step(fs, opt_state, tokens, z_fine):
        def loss_fn(f):
            z = forward_logits(cfg, merged(f), tokens)
            return jnp.mean((z_fine - z) ** 2)
        loss, grads = jax.value_and_grad(loss_fn)(fs)
        fs, opt_state = opt.update(grads, opt_state, fs)
        return fs, opt_state, loss

    rng = np.random.default_rng(5)
    for i in range(n_steps):
        pick = rng.integers(0, calib.shape[0], dcfg.batch_size)
        tokens = jnp.asarray(calib[pick])
        fs_loss = step(train, opt_state, tokens, fine_logits(tokens))
        train, opt_state, loss = fs_loss
        if i % 50 == 0:
            print(f"[{tag}] step {i:4d} logit-mse {float(loss):.6f}",
                  flush=True)
    return {n: (np.asarray(a), np.asarray(b)) for n, (a, b) in train.items()}


def cumulative_explained_variance(delta: np.ndarray) -> np.ndarray:
    """CEV series for Figure 2: cumsum(σ²)/sum(σ²)."""
    s = np.linalg.svd(delta, compute_uv=False)
    e = s.astype(np.float64) ** 2
    return np.cumsum(e) / np.sum(e)
