"""BitDelta compression: 1-bit quantization (Eq. 1-4) + scale distillation
(Eq. 5), plus the iterative multi-mask variant (Fig. 3 / Table 9).

Stage 1 — quantization: for every transformer-block linear,
    Δ = W_fine − W_base;  Δ̂ = α · Sign(Δ);  α = mean|Δ|
packed to one bit per weight (``kernels.ref.pack_signs`` ABI).

Stage 2 — scale distillation: freeze the sign matrices, treat the per-matrix
scales α as the only trainable parameters, and minimise
    E_x || Z_fine(x) − Z_bin(x; α) ||²
over a calibration set (paper: 800 C4 samples of length 128, batch 4, Adam
lr=1e-4, ~200 steps). The forward of the binarized model goes through the
real L1 kernel path (:func:`model.logits_bitdelta`), so the α* we ship are
optimal for the serving-path numerics.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import DistillConfig, ModelConfig
from .kernels.ref import pack_signs_np, unpack_signs_np
from .model import Params, forward_logits, nonlinear_names
from .train import Adam


# ---------------------------------------------------------------------------
# Stage 1: 1-bit quantization
# ---------------------------------------------------------------------------


def quantize_deltas(cfg: ModelConfig, base: Params, fine: Params
                    ) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
    """Quantize every linear's delta. Returns (bits, scales):
    bits[name] u8 [N, M/8]; scales f32 [n_linears] in linear_names order."""
    bits, scales = {}, []
    for name in cfg.linear_names():
        delta = np.asarray(fine[name], np.float32) - \
            np.asarray(base[name], np.float32)
        bits[name] = pack_signs_np(delta)
        scales.append(np.mean(np.abs(delta)))
    return bits, np.asarray(scales, np.float32)


def tenant_extras(cfg: ModelConfig, fine: Params) -> Params:
    """Per-tenant full-precision params (embeddings, norms, head)."""
    return {n: jnp.asarray(fine[n]) for n in nonlinear_names(cfg)}


# ---------------------------------------------------------------------------
# Stage 2: scale distillation
# ---------------------------------------------------------------------------


def calibration_batches(corpus: str, dcfg: DistillConfig, seed: int = 99
                        ) -> np.ndarray:
    """Fixed calibration slice: n_samples windows of seq_len tokens, the
    same subset for every model (paper controls for seed variation)."""
    data = np.frombuffer(corpus.encode("utf-8"), dtype=np.uint8)
    rng = np.random.default_rng(seed)
    starts = rng.integers(0, len(data) - dcfg.seq_len - 1,
                          size=dcfg.n_samples)
    idx = starts[:, None] + np.arange(dcfg.seq_len)[None, :]
    return data[idx].astype(np.int32)          # [n_samples, seq_len]


def distill_scales(cfg: ModelConfig, base: Params, fine: Params,
                   bits: Dict[str, np.ndarray], scales0: np.ndarray,
                   calib: np.ndarray, dcfg: DistillConfig,
                   rope_scale: float = 1.0, tag: str = "distill",
                   steps: int | None = None) -> np.ndarray:
    """Optimise the scale vector α by logit-matching the fine-tuned model
    over the calibration set. Only |linears| scalars train (a single
    parameter per weight matrix — paper §3.2).

    Gradients flow through an exact jnp twin of the kernel path: the
    binarized weight is materialised as ``W_base + α·Sign(Δ)`` (the sign
    matrices are frozen constants) and pushed through the dense forward.
    This is the same function the Pallas serving path computes — the twin
    is cross-checked against :func:`model.logits_bitdelta` in the pytest
    suite — but it is differentiable w.r.t. α, which ``pallas_call`` is
    not."""
    lin = cfg.linear_names()
    signs = {}
    for name in lin:
        _, m = cfg.linear_shape(name)
        signs[name] = jnp.asarray(unpack_signs_np(bits[name], m))
    base_j = {n: jnp.asarray(base[n]) for n in lin}
    extras = {n: jnp.asarray(fine[n]) for n in nonlinear_names(cfg)}

    def binarized(alpha):
        p = dict(extras)
        for i, name in enumerate(lin):
            p[name] = base_j[name] + alpha[i] * signs[name]
        return p

    n_steps = steps if steps is not None else dcfg.steps
    opt = Adam(dcfg.lr, betas=dcfg.betas, eps=dcfg.eps)
    alpha = jnp.asarray(scales0)
    opt_state = opt.init(alpha)

    @jax.jit
    def step(alpha, opt_state, tokens, z_fine):
        def loss_fn(a):
            z_bin = forward_logits(cfg, binarized(a), tokens, rope_scale)
            return jnp.mean((z_fine - z_bin) ** 2)
        loss, grads = jax.value_and_grad(loss_fn)(alpha)
        alpha, opt_state = opt.update(grads, opt_state, alpha)
        return alpha, opt_state, loss

    @jax.jit
    def fine_logits(tokens):
        return forward_logits(cfg, fine, tokens, rope_scale)

    rng = np.random.default_rng(7)
    loss = jnp.array(0.0)
    for i in range(n_steps):
        pick = rng.integers(0, calib.shape[0], dcfg.batch_size)
        tokens = jnp.asarray(calib[pick])
        z_fine = fine_logits(tokens)
        alpha, opt_state, loss = step(alpha, opt_state, tokens, z_fine)
        if i % 50 == 0:
            print(f"[{tag}] step {i:4d} logit-mse {float(loss):.6f}",
                  flush=True)
    print(f"[{tag}] done, logit-mse {float(loss):.6f}", flush=True)
    return np.asarray(alpha)


# ---------------------------------------------------------------------------
# Iterative BitDelta (Fig. 3 / Table 9): successive 1-bit masks
# ---------------------------------------------------------------------------


def iterative_bitdelta(cfg: ModelConfig, base: Params, fine: Params,
                       levels: int
                       ) -> List[Tuple[Dict[str, np.ndarray], np.ndarray]]:
    """Apply BitDelta ``levels`` times, each time treating the previous
    compressed model as the base (paper §4.2 "Ablation over fidelity of
    Δ"). Returns a list of (bits, scales) — one 1-bit mask per level, each
    with its own independent scale factors."""
    masks: List[Tuple[Dict[str, np.ndarray], np.ndarray]] = []
    residual = {n: np.asarray(fine[n], np.float32) -
                np.asarray(base[n], np.float32)
                for n in cfg.linear_names()}
    for _ in range(levels):
        bits, scales = {}, []
        for i, name in enumerate(cfg.linear_names()):
            d = residual[name]
            bits[name] = pack_signs_np(d)
            a = float(np.mean(np.abs(d)))
            scales.append(a)
            _, m = cfg.linear_shape(name)
            residual[name] = d - a * unpack_signs_np(bits[name], m)
        masks.append((bits, np.asarray(scales, np.float32)))
    return masks


def apply_masks(cfg: ModelConfig, base: Params,
                masks: List[Tuple[Dict[str, np.ndarray], np.ndarray]],
                extras_from: Params) -> Params:
    """Reconstruct the dense model from base + k 1-bit masks."""
    out = {n: jnp.asarray(extras_from[n]) for n in nonlinear_names(cfg)}
    for name in cfg.linear_names():
        _, m = cfg.linear_shape(name)
        w = np.asarray(base[name], np.float32).copy()
        for bits, scales in masks:
            i = cfg.linear_names().index(name)
            w += scales[i] * unpack_signs_np(bits[name], m)
        out[name] = jnp.asarray(w)
    return out


# ---------------------------------------------------------------------------
# Size accounting (Table 5)
# ---------------------------------------------------------------------------


def delta_size_bytes(cfg: ModelConfig, fp_bytes: int = 4) -> dict:
    """Bytes of one BitDelta-compressed delta vs. the dense model, matching
    the paper's accounting: linears at 1 bit + 1 scale, everything else
    (embed/norm/head) at full precision."""
    lin_bits = sum(int(np.prod(cfg.linear_shape(n)))
                   for n in cfg.linear_names())
    extras = sum(int(np.prod(cfg.param_shape(n)))
                 for n in nonlinear_names(cfg))
    dense = (lin_bits + extras) * fp_bytes
    delta = lin_bits // 8 + len(cfg.linear_names()) * 4 + extras * fp_bytes
    return {"dense_bytes": dense, "delta_bytes": delta,
            "compression_factor": dense / delta}
