"""Build-time training: pretrain the base model, then produce the tenant
fine-tunes whose deltas BitDelta compresses.

The paper compresses *other people's* fine-tunes (Vicuna, Zephyr, ...). We
have to create our own, and we create them the same ways the paper's were
made (Table 2: "SFT-based methods, RLHF-based methods, and context
extension methods"):

* ``full``   — full-parameter SFT on a tenant dataset (Llama-2-Chat /
               WizardLM analog).
* ``rlhf``   — preference optimisation: MLE on chosen + unlikelihood on
               rejected completions (RLHF analog; changes weights through a
               different objective than SFT).
* ``rope``   — context extension by position interpolation: fine-tune with
               rope_scale=0.5 on longer sequences (Vicuna-16k analog).
* ``lora``   — rank-16 LoRA on the linears (Table 7: BitDelta applied to a
               parameter-efficient fine-tune).

Everything is plain JAX + a hand-rolled Adam (no optax on the build image).
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig, TrainConfig
from .data import encode
from .model import Params, forward_logits, init_params

# ---------------------------------------------------------------------------
# Batching
# ---------------------------------------------------------------------------


def corpus_batches(text: str, tcfg: TrainConfig, n_steps: int,
                   seed: int = 0):
    """Random contiguous windows of the corpus as (tokens, targets)."""
    data = np.frombuffer(text.encode("utf-8"), dtype=np.uint8)
    rng = np.random.default_rng(seed)
    for _ in range(n_steps):
        starts = rng.integers(0, len(data) - tcfg.seq_len - 1,
                              size=tcfg.batch_size)
        idx = starts[:, None] + np.arange(tcfg.seq_len + 1)[None, :]
        chunk = data[idx].astype(np.int32)
        yield jnp.array(chunk[:, :-1]), jnp.array(chunk[:, 1:])


def doc_batches(docs: List[str], tcfg: TrainConfig, n_steps: int,
                seed: int = 0, seq_len: Optional[int] = None):
    """Pack whole documents (Q/A pairs) into fixed-length rows."""
    sl = seq_len or tcfg.seq_len
    rng = np.random.default_rng(seed)
    stream = []
    i = 0
    order = rng.permutation(len(docs))
    for _ in range(n_steps):
        rows = np.zeros((tcfg.batch_size, sl + 1), dtype=np.int32)
        for r in range(tcfg.batch_size):
            row: List[int] = []
            while len(row) < sl + 1:
                if not stream:
                    stream = encode(docs[order[i % len(docs)]])
                    i += 1
                take = min(sl + 1 - len(row), len(stream))
                row.extend(stream[:take])
                stream = stream[take:]
            rows[r] = row
        yield jnp.array(rows[:, :-1]), jnp.array(rows[:, 1:])


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def xent_loss(cfg: ModelConfig, params: Params, tokens, targets,
              rope_scale: float = 1.0):
    logits = forward_logits(cfg, params, tokens, rope_scale)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def preference_loss(cfg: ModelConfig, params: Params, prompt_toks,
                    chosen_toks, rejected_toks, chosen_mask, rejected_mask,
                    beta: float = 0.3):
    """MLE on the chosen completion plus an unlikelihood penalty on the
    rejected one — a lightweight RLHF stand-in that perturbs the weights
    through a preference signal rather than plain SFT."""

    def comp_logp(completion, mask):
        toks = jnp.concatenate([prompt_toks, completion], axis=1)
        logits = forward_logits(cfg, params, toks[:, :-1])
        logp = jax.nn.log_softmax(logits, axis=-1)
        tgt = toks[:, 1:]
        tok_lp = jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        plen = prompt_toks.shape[1] - 1
        comp_lp = tok_lp[:, plen:]
        return comp_lp, logp[:, plen:], mask

    ch_lp, _, ch_m = comp_logp(chosen_toks, chosen_mask)
    rj_lp, _, rj_m = comp_logp(rejected_toks, rejected_mask)
    mle = -jnp.sum(ch_lp * ch_m) / jnp.maximum(jnp.sum(ch_m), 1.0)
    # unlikelihood: -log(1 - p(rejected token))
    unlike = -jnp.log1p(-jnp.clip(jnp.exp(rj_lp), 0.0, 1.0 - 1e-6))
    ul = jnp.sum(unlike * rj_m) / jnp.maximum(jnp.sum(rj_m), 1.0)
    return mle + beta * ul


# ---------------------------------------------------------------------------
# Hand-rolled Adam
# ---------------------------------------------------------------------------


class Adam:
    def __init__(self, lr: float, betas=(0.9, 0.999), eps: float = 1e-8,
                 warmup: int = 0):
        self.lr, self.betas, self.eps, self.warmup = lr, betas, eps, warmup

    def init(self, params):
        zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
        return {"m": zeros,
                "v": jax.tree_util.tree_map(jnp.zeros_like, params),
                "t": jnp.array(0, jnp.int32)}

    def update(self, grads, state, params):
        b1, b2 = self.betas
        t = state["t"] + 1
        lr = self.lr * jnp.minimum(1.0, t / max(self.warmup, 1))
        m = jax.tree_util.tree_map(
            lambda mm, g: b1 * mm + (1 - b1) * g, state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda vv, g: b2 * vv + (1 - b2) * g * g, state["v"], grads)
        mhat_scale = 1.0 / (1 - b1 ** t.astype(jnp.float32))
        vhat_scale = 1.0 / (1 - b2 ** t.astype(jnp.float32))
        new_params = jax.tree_util.tree_map(
            lambda p, mm, vv: p - lr * (mm * mhat_scale) /
            (jnp.sqrt(vv * vhat_scale) + self.eps),
            params, m, v)
        return new_params, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# Training loops
# ---------------------------------------------------------------------------


def train_lm(cfg: ModelConfig, params: Params, batches, lr: float,
             warmup: int, rope_scale: float = 1.0,
             log_every: int = 50, tag: str = "train") -> Params:
    opt = Adam(lr, warmup=warmup)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, tokens, targets):
        loss, grads = jax.value_and_grad(
            lambda p: xent_loss(cfg, p, tokens, targets, rope_scale))(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    t0 = time.time()
    for i, (tokens, targets) in enumerate(batches):
        params, opt_state, loss = step(params, opt_state, tokens, targets)
        if i % log_every == 0:
            print(f"[{tag}] step {i:4d} loss {float(loss):.4f} "
                  f"({time.time() - t0:.0f}s)", flush=True)
    print(f"[{tag}] done, final loss {float(loss):.4f}", flush=True)
    return params


def pretrain(cfg: ModelConfig, tcfg: TrainConfig, corpus: str) -> Params:
    params = init_params(cfg, jax.random.PRNGKey(tcfg.seed))
    batches = corpus_batches(corpus, tcfg, tcfg.pretrain_steps,
                             seed=tcfg.seed + 1)
    return train_lm(cfg, params, batches, tcfg.lr, tcfg.warmup,
                    tag=f"pretrain/{cfg.name}")


def finetune_full(cfg: ModelConfig, tcfg: TrainConfig, base: Params,
                  docs: List[str], tag: str,
                  rope_scale: float = 1.0,
                  seq_len: Optional[int] = None,
                  steps: Optional[int] = None) -> Params:
    """Full-parameter fine-tune (every weight trains — the regime the
    paper says LoRA can't match and BitDelta targets)."""
    batches = doc_batches(docs, tcfg, steps or tcfg.finetune_steps,
                          seed=tcfg.seed + 7, seq_len=seq_len)
    return train_lm(cfg, dict(base), batches, tcfg.finetune_lr,
                    warmup=10, rope_scale=rope_scale, tag=tag)


def finetune_rlhf(cfg: ModelConfig, tcfg: TrainConfig, base: Params,
                  prefs: List[Tuple[str, str, str]], tag: str) -> Params:
    """Preference fine-tune (MLE + unlikelihood)."""
    params = dict(base)
    opt = Adam(tcfg.finetune_lr, warmup=10)
    opt_state = opt.init(params)

    # fixed-size prompt/completion windows for jit friendliness
    plen = max(len(encode(p)) for p, _, _ in prefs)
    clen = max(max(len(encode(c)), len(encode(r))) for _, c, r in prefs)

    def pad(toks, n):
        a = np.zeros(n, np.int32)
        a[:len(toks)] = toks
        return a, (np.arange(n) < len(toks)).astype(np.float32)

    rng = np.random.default_rng(tcfg.seed + 11)

    @jax.jit
    def step(params, opt_state, pt, ct, rt, cm, rm):
        loss, grads = jax.value_and_grad(
            lambda p: preference_loss(cfg, p, pt, ct, rt, cm, rm))(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    bs = tcfg.batch_size
    for i in range(tcfg.finetune_steps):
        pick = rng.integers(0, len(prefs), bs)
        pts, cts, rts, cms, rms = [], [], [], [], []
        for j in pick:
            p, c, r = prefs[j]
            pt, _ = pad(encode(p), plen)
            ct, cm = pad(encode(c), clen)
            rt, rm = pad(encode(r), clen)
            pts.append(pt); cts.append(ct); rts.append(rt)
            cms.append(cm); rms.append(rm)
        params, opt_state, loss = step(
            params, opt_state,
            jnp.array(pts), jnp.array(cts), jnp.array(rts),
            jnp.array(cms), jnp.array(rms))
        if i % 40 == 0:
            print(f"[{tag}] step {i:4d} loss {float(loss):.4f}", flush=True)
    return params


def finetune_lora(cfg: ModelConfig, tcfg: TrainConfig, base: Params,
                  docs: List[str], tag: str, rank: int = 16,
                  seed: int = 21) -> Params:
    """LoRA fine-tune: train rank-r factors on every linear, freeze the
    rest, then *merge* (W + BA) so the result is an ordinary fine-tuned
    checkpoint — exactly what BitDelta sees in Table 7."""
    key = jax.random.PRNGKey(seed)
    lora: Dict[str, Tuple[jnp.ndarray, jnp.ndarray]] = {}
    for name in cfg.linear_names():
        n, m = cfg.linear_shape(name)
        key, sub = jax.random.split(key)
        a = jax.random.normal(sub, (rank, m), jnp.float32) * (m ** -0.5)
        b = jnp.zeros((n, rank), jnp.float32)
        lora[name] = (a, b)

    def merged(lora_params):
        p = dict(base)
        for name, (a, b) in lora_params.items():
            p[name] = base[name] + b @ a
        return p

    opt = Adam(tcfg.finetune_lr * 3, warmup=10)
    opt_state = opt.init(lora)

    @jax.jit
    def step(lora_params, opt_state, tokens, targets):
        def loss_fn(lp):
            return xent_loss(cfg, merged(lp), tokens, targets)
        loss, grads = jax.value_and_grad(loss_fn)(lora_params)
        lora_params, opt_state = opt.update(grads, opt_state, lora_params)
        return lora_params, opt_state, loss

    batches = doc_batches(docs, tcfg, tcfg.finetune_steps, seed=seed)
    for i, (tokens, targets) in enumerate(batches):
        lora, opt_state, loss = step(lora, opt_state, tokens, targets)
        if i % 40 == 0:
            print(f"[{tag}] step {i:4d} loss {float(loss):.4f}", flush=True)
    return merged(lora)
