"""L2: Llama-style transformer in functional JAX, with the four serving
forwards that get AOT-lowered to HLO for the rust runtime.

Weight layout
-------------
Parameters are a flat ``{name: array}`` dict; the canonical name order
(``ModelConfig.param_names()``) is the ABI between python and rust — HLO
executables take weights as positional parameters in exactly that order.

Four execution modes (DESIGN.md §5), all sharing this skeleton:

* ``dense``    — plain fine-tuned/base model, one weight set, batched x.
* ``naive``    — B *distinct* dense models stacked per-tenant (the paper's
                 naive multi-tenant baseline that OOMs in Figs. 5/6).
* ``bitdelta`` — Eq. 6: shared base linears + per-tenant packed 1-bit
                 deltas routed through the L1 Pallas kernel
                 (:func:`kernels.binary_gemm.binary_gemm`). Embeddings,
                 norms and LM head stay per-tenant at full precision,
                 matching the paper (Table 5: only transformer-block
                 linears are quantized).
* ``lora``     — shared base linears + per-tenant rank-r factors through
                 :func:`kernels.lora_gemm.lora_gemm` (the S-LoRA baseline).

KV cache ABI
------------
``k_cache, v_cache: f32 [n_layers, B, n_heads, max_seq, head_dim]``; a
per-sequence ``pos: i32 [B]`` marks how many slots are valid. Decode writes
slot ``pos[b]`` and attends to slots ``0..=pos[b]``. RoPE supports a
per-sequence ``rope_scale`` (position-interpolation context extension, the
Vicuna-16k analog tenant).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .kernels.binary_gemm import binary_gemm
from .kernels.lora_gemm import lora_gemm

Params = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key) -> Params:
    """Scaled-normal init; norms start at 1."""
    params = {}
    for name in cfg.param_names():
        shape = cfg.param_shape(name)
        key, sub = jax.random.split(key)
        if name.endswith("norm"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[-1]
            params[name] = (jax.random.normal(sub, shape, jnp.float32)
                            * (fan_in ** -0.5))
    return params


def flatten_params(cfg: ModelConfig, params: Params):
    return [params[n] for n in cfg.param_names()]


def unflatten_params(cfg: ModelConfig, flat) -> Params:
    names = cfg.param_names()
    assert len(flat) == len(names)
    return dict(zip(names, flat))


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def rmsnorm(x, w, eps: float):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope_angles(cfg: ModelConfig, positions):
    """positions: f32 [...]; returns (cos, sin) of shape [..., head_dim/2]."""
    half = cfg.head_dim // 2
    freqs = cfg.rope_theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: [..., head_dim]; cos/sin broadcastable [..., head_dim/2]."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


# ---------------------------------------------------------------------------
# Linear-application strategies (one per serving mode)
# ---------------------------------------------------------------------------


class DenseWeights:
    """One shared dense weight set (base model / single fine-tune)."""

    def __init__(self, cfg: ModelConfig, params: Params):
        self.cfg, self.p = cfg, params

    def linear(self, name: str, x):           # x [B, M] -> [B, N]
        return x @ self.p[name].T

    def tensor(self, name: str):
        return self.p[name]


class NaiveWeights:
    """B distinct dense models stacked along a leading tenant axis —
    every parameter has shape [B, ...] (the multi-tenant baseline whose
    memory footprint scales with B full models)."""

    def __init__(self, cfg: ModelConfig, stacked: Params):
        self.cfg, self.p = cfg, stacked

    def linear(self, name: str, x):           # x [B, M] -> [B, N]
        return jnp.einsum("bm,bnm->bn", x, self.p[name])

    def tensor(self, name: str):
        return self.p[name]                   # [B, ...] per-tenant


class BitDeltaWeights:
    """Eq. 6: shared base linears + per-tenant packed 1-bit deltas.

    ``bits[name]``: u8 [B, N, M/8]; ``scales``: f32 [B, n_linears] in
    ``cfg.linear_names()`` order. Non-linear params per-tenant,
    full-precision ([B, ...]).
    """

    def __init__(self, cfg: ModelConfig, base: Params, bits: Params,
                 scales, tenant_extras: Params):
        self.cfg, self.base, self.bits = cfg, base, bits
        self.scales = scales
        self.extras = tenant_extras
        self.lin_idx = {n: i for i, n in enumerate(cfg.linear_names())}

    def linear(self, name: str, x):           # x [B, M] -> [B, N]
        y = x @ self.base[name].T             # shared backbone GEMM
        alpha = self.scales[:, self.lin_idx[name]]
        d = binary_gemm(self.bits[name], alpha, x[:, None, :])[:, 0, :]
        return y + d

    def tensor(self, name: str):
        return self.extras[name]              # [B, ...] per-tenant


class BitDeltaMultiWeights:
    """Fig. 3 fidelity tiers served natively: shared base linears +
    per-tenant **stacked** 1-bit mask levels, summed per linear.

    ``bits[name]``: u8 [B, L, N, M/8]; ``scales``: f32 [B, L, n_linears]
    in ``cfg.linear_names()`` order. A level with scale 0 is a no-op —
    the engine's zero-scale padding convention for batching tenants at
    different tiers. The L-loop unrolls at trace time (L <= 4), so each
    level lowers to one more batched binary GEMM over the shared
    activations.
    """

    def __init__(self, cfg: ModelConfig, base: Params, bits: Params,
                 scales, tenant_extras: Params):
        self.cfg, self.base, self.bits = cfg, base, bits
        self.scales = scales                  # [B, L, n_linears]
        self.extras = tenant_extras
        self.lin_idx = {n: i for i, n in enumerate(cfg.linear_names())}

    def linear(self, name: str, x):           # x [B, M] -> [B, N]
        y = x @ self.base[name].T             # shared backbone GEMM
        i = self.lin_idx[name]
        for lvl in range(self.scales.shape[1]):
            alpha = self.scales[:, lvl, i]
            y = y + binary_gemm(self.bits[name][:, lvl], alpha,
                                x[:, None, :])[:, 0, :]
        return y

    def tensor(self, name: str):
        return self.extras[name]              # [B, ...] per-tenant


class LoraWeights:
    """Shared base linears + per-tenant low-rank factors (S-LoRA baseline;
    also serves the post-hoc SVD-compression baseline of Table 1)."""

    def __init__(self, cfg: ModelConfig, base: Params, a_fac: Params,
                 b_fac: Params, tenant_extras: Params):
        self.cfg, self.base = cfg, base
        self.a, self.b = a_fac, b_fac
        self.extras = tenant_extras

    def linear(self, name: str, x):
        y = x @ self.base[name].T
        d = lora_gemm(self.a[name], self.b[name], x[:, None, :])[:, 0, :]
        return y + d

    def tensor(self, name: str):
        return self.extras[name]


# ---------------------------------------------------------------------------
# Full forward (training / eval / prefill) — dense weights
# ---------------------------------------------------------------------------


def forward_logits(cfg: ModelConfig, params: Params, tokens,
                   rope_scale: float = 1.0):
    """Causal LM forward. tokens: i32 [B, T] -> logits f32 [B, T, V]."""
    b, t = tokens.shape
    x = params["tok_embed"][tokens]                        # [B, T, D]
    positions = jnp.arange(t, dtype=jnp.float32) * rope_scale
    cos, sin = rope_angles(cfg, positions)                 # [T, hd/2]
    mask = jnp.tril(jnp.ones((t, t), bool))

    for layer in range(cfg.n_layers):
        pre = f"layers.{layer}."
        h = rmsnorm(x, params[pre + "attn_norm"], cfg.norm_eps)
        q = (h @ params[pre + "wq"].T).reshape(b, t, cfg.n_heads, cfg.head_dim)
        k = (h @ params[pre + "wk"].T).reshape(b, t, cfg.n_heads, cfg.head_dim)
        v = (h @ params[pre + "wv"].T).reshape(b, t, cfg.n_heads, cfg.head_dim)
        q = apply_rope(q, cos[None, :, None, :], sin[None, :, None, :])
        k = apply_rope(k, cos[None, :, None, :], sin[None, :, None, :])
        scores = jnp.einsum("bthd,bshd->bhts", q, k) * (cfg.head_dim ** -0.5)
        scores = jnp.where(mask[None, None], scores, -1e30)
        attn = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("bhts,bshd->bthd", attn, v).reshape(b, t, cfg.d_model)
        x = x + o @ params[pre + "wo"].T

        h = rmsnorm(x, params[pre + "mlp_norm"], cfg.norm_eps)
        gate = jax.nn.silu(h @ params[pre + "w_gate"].T)
        up = h @ params[pre + "w_up"].T
        x = x + (gate * up) @ params[pre + "w_down"].T

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x @ params["lm_head"].T


def prefill(cfg: ModelConfig, params: Params, tokens, length, rope_scale):
    """Prefill one sequence (B=1): full forward over a padded prompt,
    returning the logits at the last valid position and a max_seq-sized KV
    cache with slots [0, length) written.

    tokens: i32 [1, Tp]; length: i32 scalar; rope_scale: f32 scalar.
    Returns (logits [1, V], k_cache, v_cache [L, 1, H, max_seq, hd]).
    """
    b, t = tokens.shape
    x = params["tok_embed"][tokens]
    positions = jnp.arange(t, dtype=jnp.float32) * rope_scale
    cos, sin = rope_angles(cfg, positions)
    mask = jnp.tril(jnp.ones((t, t), bool))

    ks, vs = [], []
    for layer in range(cfg.n_layers):
        pre = f"layers.{layer}."
        h = rmsnorm(x, params[pre + "attn_norm"], cfg.norm_eps)
        q = (h @ params[pre + "wq"].T).reshape(b, t, cfg.n_heads, cfg.head_dim)
        k = (h @ params[pre + "wk"].T).reshape(b, t, cfg.n_heads, cfg.head_dim)
        v = (h @ params[pre + "wv"].T).reshape(b, t, cfg.n_heads, cfg.head_dim)
        q = apply_rope(q, cos[None, :, None, :], sin[None, :, None, :])
        k = apply_rope(k, cos[None, :, None, :], sin[None, :, None, :])
        ks.append(k)
        vs.append(v)
        scores = jnp.einsum("bthd,bshd->bhts", q, k) * (cfg.head_dim ** -0.5)
        scores = jnp.where(mask[None, None], scores, -1e30)
        attn = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("bhts,bshd->bthd", attn, v).reshape(b, t, cfg.d_model)
        x = x + o @ params[pre + "wo"].T
        h = rmsnorm(x, params[pre + "mlp_norm"], cfg.norm_eps)
        gate = jax.nn.silu(h @ params[pre + "w_gate"].T)
        up = h @ params[pre + "w_up"].T
        x = x + (gate * up) @ params[pre + "w_down"].T

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["lm_head"].T                        # [1, Tp, V]
    idx = jnp.clip(length - 1, 0, t - 1)
    last = jax.lax.dynamic_slice_in_dim(logits, 0, 1, axis=0)
    last = jnp.squeeze(
        jax.lax.dynamic_slice(last, (0, idx, 0), (1, 1, cfg.vocab_size)),
        axis=1)

    # Stack to [L, 1, H, Tp, hd], then pad the time axis to max_seq.
    k_all = jnp.stack([k.transpose(0, 2, 1, 3) for k in ks])
    v_all = jnp.stack([v.transpose(0, 2, 1, 3) for v in vs])
    pad = cfg.max_seq_len - t
    k_cache = jnp.pad(k_all, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
    v_cache = jnp.pad(v_all, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
    return last, k_cache, v_cache


# ---------------------------------------------------------------------------
# Batched decode step — mode-generic
# ---------------------------------------------------------------------------


def decode_step(cfg: ModelConfig, weights, k_cache, v_cache, pos, token,
                rope_scale):
    """One decode step for a batch of B sequences (possibly B tenants).

    weights: one of the *Weights strategies above.
    k_cache/v_cache: f32 [L, B, H, S, hd];  pos: i32 [B]  (slot to write,
    == current sequence length);  token: i32 [B];  rope_scale: f32 [B].

    Returns (logits [B, V], k_cache', v_cache').
    """
    b = token.shape[0]
    s = cfg.max_seq_len
    emb = weights.tensor("tok_embed")
    if emb.ndim == 3:                          # per-tenant embed [B, V, D]
        x = jnp.einsum("bv,bvd->bd",
                       jax.nn.one_hot(token, cfg.vocab_size), emb)
    else:
        x = emb[token]
    cos, sin = rope_angles(cfg, pos.astype(jnp.float32) * rope_scale)

    slot_ids = jnp.arange(s)
    attn_mask = slot_ids[None, :] <= pos[:, None]          # [B, S]

    new_k, new_v = [], []
    for layer in range(cfg.n_layers):
        pre = f"layers.{layer}."
        nw = weights.tensor(pre + "attn_norm")
        h = rmsnorm(x, nw, cfg.norm_eps)
        q = weights.linear(pre + "wq", h).reshape(b, cfg.n_heads, cfg.head_dim)
        k = weights.linear(pre + "wk", h).reshape(b, cfg.n_heads, cfg.head_dim)
        v = weights.linear(pre + "wv", h).reshape(b, cfg.n_heads, cfg.head_dim)
        q = apply_rope(q, cos[:, None, :], sin[:, None, :])
        k = apply_rope(k, cos[:, None, :], sin[:, None, :])

        # write slot pos[b] of this layer's cache
        kc, vc = k_cache[layer], v_cache[layer]            # [B, H, S, hd]
        onehot = (slot_ids[None, :] == pos[:, None]).astype(jnp.float32)
        kc = kc * (1 - onehot)[:, None, :, None] + \
            k[:, :, None, :] * onehot[:, None, :, None]
        vc = vc * (1 - onehot)[:, None, :, None] + \
            v[:, :, None, :] * onehot[:, None, :, None]
        new_k.append(kc)
        new_v.append(vc)

        scores = jnp.einsum("bhd,bhsd->bhs", q, kc) * (cfg.head_dim ** -0.5)
        scores = jnp.where(attn_mask[:, None, :], scores, -1e30)
        attn = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("bhs,bhsd->bhd", attn, vc).reshape(b, cfg.d_model)
        x = x + weights.linear(pre + "wo", o)

        h = rmsnorm(x, weights.tensor(pre + "mlp_norm"), cfg.norm_eps)
        gate = jax.nn.silu(weights.linear(pre + "w_gate", h))
        up = weights.linear(pre + "w_up", h)
        x = x + weights.linear(pre + "w_down", gate * up)

    x = rmsnorm(x, weights.tensor("final_norm"), cfg.norm_eps)
    head = weights.tensor("lm_head")
    if head.ndim == 3:                         # per-tenant head [B, V, D]
        logits = jnp.einsum("bd,bvd->bv", x, head)
    else:
        logits = x @ head.T
    return logits, jnp.stack(new_k), jnp.stack(new_v)


# ---------------------------------------------------------------------------
# Mode-specific entry points (these are what aot.py lowers)
# ---------------------------------------------------------------------------


def nonlinear_names(cfg: ModelConfig):
    """Params that stay full-precision per tenant under BitDelta/LoRA
    (embeddings, norms, LM head — paper Table 5 keeps these fp16)."""
    lin = set(cfg.linear_names())
    return [n for n in cfg.param_names() if n not in lin]


def decode_dense(cfg, flat_params, k_cache, v_cache, pos, token, rope_scale):
    weights = DenseWeights(cfg, unflatten_params(cfg, flat_params))
    return decode_step(cfg, weights, k_cache, v_cache, pos, token, rope_scale)


def decode_naive(cfg, flat_stacked, k_cache, v_cache, pos, token, rope_scale):
    weights = NaiveWeights(cfg, unflatten_params(cfg, flat_stacked))
    return decode_step(cfg, weights, k_cache, v_cache, pos, token, rope_scale)


def decode_bitdelta(cfg, flat_base_linears, flat_bits, scales, flat_extras,
                    k_cache, v_cache, pos, token, rope_scale):
    lin = cfg.linear_names()
    base = dict(zip(lin, flat_base_linears))
    bits = dict(zip(lin, flat_bits))
    extras = dict(zip(nonlinear_names(cfg), flat_extras))
    weights = BitDeltaWeights(cfg, base, bits, scales, extras)
    return decode_step(cfg, weights, k_cache, v_cache, pos, token, rope_scale)


def decode_bitdelta_multi(cfg, flat_base_linears, flat_bits, scales,
                          flat_extras, k_cache, v_cache, pos, token,
                          rope_scale):
    """Multi-level decode step: bits [B, L, N, M/8], scales
    [B, L, n_linears] — the `decode_bitdelta_l{L}` ABI."""
    lin = cfg.linear_names()
    base = dict(zip(lin, flat_base_linears))
    bits = dict(zip(lin, flat_bits))
    extras = dict(zip(nonlinear_names(cfg), flat_extras))
    weights = BitDeltaMultiWeights(cfg, base, bits, scales, extras)
    return decode_step(cfg, weights, k_cache, v_cache, pos, token, rope_scale)


def decode_lora(cfg, flat_base_linears, flat_a, flat_b, flat_extras,
                k_cache, v_cache, pos, token, rope_scale):
    lin = cfg.linear_names()
    base = dict(zip(lin, flat_base_linears))
    a = dict(zip(lin, flat_a))
    bm = dict(zip(lin, flat_b))
    extras = dict(zip(nonlinear_names(cfg), flat_extras))
    weights = LoraWeights(cfg, base, a, bm, extras)
    return decode_step(cfg, weights, k_cache, v_cache, pos, token, rope_scale)


def logits_bitdelta(cfg, flat_base_linears, flat_bits, scales, flat_extras,
                    tokens, rope_scale: float = 1.0):
    """Full causal forward through the decomposed Eq. 6 path (B tenants,
    full sequences) — used by scale distillation and to cross-check that
    the serving-path numerics equal the dequantized dense path."""
    lin = cfg.linear_names()
    base = dict(zip(lin, flat_base_linears))
    bits = dict(zip(lin, flat_bits))
    extras = dict(zip(nonlinear_names(cfg), flat_extras))
    lin_idx = {n: i for i, n in enumerate(lin)}

    b, t = tokens.shape
    emb = extras["tok_embed"]
    if emb.ndim == 3:                          # per-tenant [B, V, D]
        x = jnp.einsum("btv,bvd->btd",
                       jax.nn.one_hot(tokens, cfg.vocab_size), emb)
    else:
        x = emb[tokens]
    positions = jnp.arange(t, dtype=jnp.float32) * rope_scale
    cos, sin = rope_angles(cfg, positions)
    mask = jnp.tril(jnp.ones((t, t), bool))

    def norm_w(name):
        w = extras[name]
        return w[:, None, :] if w.ndim == 2 else w

    def lin_seq(name, h):                      # h [B, T, D]
        y = jnp.einsum("btm,nm->btn", h, base[name])
        alpha = scales[:, lin_idx[name]]
        return y + binary_gemm(bits[name], alpha, h)

    for layer in range(cfg.n_layers):
        pre = f"layers.{layer}."
        h = rmsnorm(x, norm_w(pre + "attn_norm"), cfg.norm_eps)
        q = lin_seq(pre + "wq", h).reshape(b, t, cfg.n_heads, cfg.head_dim)
        k = lin_seq(pre + "wk", h).reshape(b, t, cfg.n_heads, cfg.head_dim)
        v = lin_seq(pre + "wv", h).reshape(b, t, cfg.n_heads, cfg.head_dim)
        q = apply_rope(q, cos[None, :, None, :], sin[None, :, None, :])
        k = apply_rope(k, cos[None, :, None, :], sin[None, :, None, :])
        scores = jnp.einsum("bthd,bshd->bhts", q, k) * (cfg.head_dim ** -0.5)
        scores = jnp.where(mask[None, None], scores, -1e30)
        attn = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("bhts,bshd->bthd", attn, v).reshape(b, t, cfg.d_model)
        x = x + lin_seq(pre + "wo", o)
        h = rmsnorm(x, norm_w(pre + "mlp_norm"), cfg.norm_eps)
        gate = jax.nn.silu(lin_seq(pre + "w_gate", h))
        up = lin_seq(pre + "w_up", h)
        x = x + lin_seq(pre + "w_down", gate * up)

    x = rmsnorm(x, norm_w("final_norm"), cfg.norm_eps)
    head = extras["lm_head"]
    if head.ndim == 3:
        return jnp.einsum("btd,bvd->btv", x, head)
    return x @ head.T


def materialize_bitdelta(cfg: ModelConfig, base: Params, bits: Params,
                         scales, extras: Params) -> Params:
    """Dequantize Δ̂ = α·Sign(Δ) and fold into dense weights — exactly the
    numbers the serving path computes, as a plain dense model (fast
    evaluation path; cross-checked against :func:`logits_bitdelta`)."""
    from .kernels.ref import unpack_signs

    out = dict(extras)
    for i, name in enumerate(cfg.linear_names()):
        _, m = cfg.linear_shape(name)
        delta = scales[i] * unpack_signs(bits[name], m)
        out[name] = base[name] + delta
    return out
