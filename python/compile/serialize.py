"""BDW container format — the weight interchange between python (writer,
build time) and rust (`rust/src/store/bdw.rs`, reader).

One container serves every role; roles are distinguished by tensor naming
conventions plus the manifest:

* **model files**  — tensors named per ``ModelConfig.param_names()``.
* **delta files**  — ``bits.{level}.{linear}`` (u8 packed signs),
  ``scales.{level}`` (f32 [n_linears] in ``linear_names()`` order), and
  ``extra.{name}`` (full-precision per-tenant embeddings/norms/head).
* **lora/svd files** — ``lora_a.{linear}`` / ``lora_b.{linear}`` factors
  plus ``extra.{name}``.

Binary layout (little-endian)::

    magic   4s  = b"BDW1"
    version u32 = 1
    count   u32
    repeat count times:
        name_len u16, name bytes (utf-8)
        dtype    u8          0 = f32, 1 = u8, 2 = i32
        ndim     u8
        dims     u32 * ndim
        size     u64         payload bytes
        payload  (row-major, C order)
    fnv1a   u64              checksum over every payload byte, in order

The FNV-1a footer lets rust detect truncated/corrupted artifact files
cheaply at load time.
"""

from __future__ import annotations

import struct
from typing import Dict, List, Tuple

import numpy as np

MAGIC = b"BDW1"
VERSION = 1

_DTYPES = {np.dtype(np.float32): 0, np.dtype(np.uint8): 1,
           np.dtype(np.int32): 2}
_DTYPES_INV = {0: np.float32, 1: np.uint8, 2: np.int32}

FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x100000001B3
_MASK = 0xFFFFFFFFFFFFFFFF


def _fnv1a(state: int, data: bytes) -> int:
    # Byte-chunked FNV-1a; vectorised enough for our file sizes.
    for b in data:
        state = ((state ^ b) * FNV_PRIME) & _MASK
    return state


def _fnv1a_np(state: int, data: bytes) -> int:
    """Fast FNV-1a using 64-bit numpy lanes is not possible (the hash is
    strictly sequential), but a C-speed loop via int.from_bytes chunks of
    1 is too slow for MBs — so we precompute with numpy on uint64 via
    Horner steps in blocks of 8 bytes worth of scalar python. For our file
    sizes (< 20 MB) a small optimisation suffices: process via memoryview
    in python but short-circuit all-zero pages."""
    mv = memoryview(data)
    step = 1 << 16
    for off in range(0, len(mv), step):
        state = _fnv1a(state, mv[off:off + step].tobytes())
    return state


def write_bdw(path: str, tensors: List[Tuple[str, np.ndarray]]) -> None:
    """Write tensors (ordered!) to a BDW container."""
    chunks = [MAGIC, struct.pack("<II", VERSION, len(tensors))]
    csum = FNV_OFFSET
    for name, arr in tensors:
        arr = np.ascontiguousarray(arr)
        if arr.dtype not in _DTYPES:
            arr = arr.astype(np.float32)
        nb = name.encode("utf-8")
        chunks.append(struct.pack("<H", len(nb)))
        chunks.append(nb)
        chunks.append(struct.pack("<BB", _DTYPES[arr.dtype], arr.ndim))
        chunks.append(struct.pack(f"<{arr.ndim}I", *arr.shape))
        payload = arr.tobytes()
        chunks.append(struct.pack("<Q", len(payload)))
        chunks.append(payload)
        csum = _fnv1a_np(csum, payload)
    chunks.append(struct.pack("<Q", csum))
    with open(path, "wb") as f:
        f.write(b"".join(chunks))


def read_bdw(path: str) -> Dict[str, np.ndarray]:
    """Read a BDW container (used by the pytest round-trip suite; rust has
    its own reader that must agree bit-for-bit)."""
    with open(path, "rb") as f:
        buf = f.read()
    assert buf[:4] == MAGIC, "bad magic"
    version, count = struct.unpack_from("<II", buf, 4)
    assert version == VERSION
    off = 12
    out: Dict[str, np.ndarray] = {}
    csum = FNV_OFFSET
    for _ in range(count):
        (nlen,) = struct.unpack_from("<H", buf, off)
        off += 2
        name = buf[off:off + nlen].decode("utf-8")
        off += nlen
        dtype_id, ndim = struct.unpack_from("<BB", buf, off)
        off += 2
        dims = struct.unpack_from(f"<{ndim}I", buf, off)
        off += 4 * ndim
        (size,) = struct.unpack_from("<Q", buf, off)
        off += 8
        payload = buf[off:off + size]
        off += size
        csum = _fnv1a_np(csum, payload)
        arr = np.frombuffer(payload, dtype=_DTYPES_INV[dtype_id]).reshape(dims)
        out[name] = arr
    (want,) = struct.unpack_from("<Q", buf, off)
    assert csum == want, "checksum mismatch"
    return out


# ---------------------------------------------------------------------------
# Role-specific writers
# ---------------------------------------------------------------------------


def write_model(path: str, cfg, params) -> None:
    tensors = [(n, np.asarray(params[n], np.float32))
               for n in cfg.param_names()]
    write_bdw(path, tensors)


def write_delta(path: str, cfg, masks, extras) -> None:
    """masks: list of (bits dict, scales array) per level; extras: dict of
    per-tenant full-precision params."""
    tensors: List[Tuple[str, np.ndarray]] = []
    for level, (bits, scales) in enumerate(masks):
        tensors.append((f"scales.{level}",
                        np.asarray(scales, np.float32)))
        for name in cfg.linear_names():
            tensors.append((f"bits.{level}.{name}",
                            np.asarray(bits[name], np.uint8)))
    for name, arr in extras.items():
        tensors.append((f"extra.{name}", np.asarray(arr, np.float32)))
    write_bdw(path, tensors)


def write_lora(path: str, cfg, factors, extras) -> None:
    """factors in **kernel ABI**: name -> (a [r, M] down-proj,
    b [N, r] up-proj), i.e. delta = b @ a."""
    tensors: List[Tuple[str, np.ndarray]] = []
    for name in cfg.linear_names():
        a, b = factors[name]
        assert a.shape[0] == b.shape[1], (name, a.shape, b.shape)
        tensors.append((f"lora_a.{name}", np.asarray(a, np.float32)))
        tensors.append((f"lora_b.{name}", np.asarray(b, np.float32)))
    for name, arr in extras.items():
        tensors.append((f"extra.{name}", np.asarray(arr, np.float32)))
    write_bdw(path, tensors)
