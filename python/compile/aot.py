"""AOT artifact pipeline — the single entry point of ``make artifacts``.

Runs ONCE at build time (python is never on the request path):

1. generate the synthetic world, corpora and eval sets;
2. pretrain the base models and train every tenant fine-tune;
3. compress: BitDelta (quantize + scale distillation), iterative
   multi-mask deltas, SVD baselines, quantized-base variants (Table 6);
4. serialize weights/deltas to BDW containers;
5. lower every serving executable to **HLO text** (never ``.serialize()``
   — xla_extension 0.5.1 rejects jax≥0.5's 64-bit-id protos; the text
   parser reassigns ids, see /opt/xla-example/README.md);
6. write ``manifest.json`` describing everything for the rust runtime.

Usage: ``cd python && python -m compile.aot --out-dir ../artifacts``
(add ``--quick`` for a CI-sized build: fewer steps, sim-s only).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import bitdelta as bd
from . import data as D
from . import quant as Q
from . import svd_baseline as S
from . import train as T
from .config import CONFIGS, DistillConfig, ModelConfig, TrainConfig
from .model import (decode_bitdelta, decode_bitdelta_multi, decode_dense,
                    decode_lora, decode_naive, forward_logits,
                    logits_bitdelta, nonlinear_names, prefill)
from .serialize import read_bdw, write_delta, write_lora, write_model

from jax._src.lib import xla_client as xc


# ---------------------------------------------------------------------------
# HLO lowering
# ---------------------------------------------------------------------------


def to_hlo_text(lowered, *, untuple: bool = False) -> str:
    """``untuple=True`` stops forcing the root into a tuple
    (``return_tuple=False``). A multi-result computation keeps its
    natural root tuple either way — PJRT execution untuples the root's
    leaves, so each decode output (logits, k, v) arrives as its own
    device buffer, the prerequisite for feeding outputs straight back
    as next-step inputs (device-resident KV). What the flag protects
    is the single-output exports: those stay force-wrapped in a
    1-tuple, which the rust ``run_buffers`` tuple path expects."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=not untuple)
    return comp.as_hlo_text()


def export_hlo(fn, args, path: str, tag: str, *,
               untuple: bool = False) -> dict:
    t0 = time.time()
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered, untuple=untuple)
    with open(path, "w") as f:
        f.write(text)
    print(f"[aot] lowered {tag} -> {os.path.basename(path)} "
          f"({len(text)} chars, {time.time() - t0:.1f}s)", flush=True)
    entry = {"path": os.path.basename(path)}
    if untuple:
        entry["untupled"] = True
    return entry


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


# ---------------------------------------------------------------------------
# Executable argument specs (the python↔rust ABI)
# ---------------------------------------------------------------------------


def dense_param_specs(cfg: ModelConfig, batch: int | None = None):
    """Weight specs in canonical order; leading tenant axis if ``batch``."""
    out = []
    for n in cfg.param_names():
        s = cfg.param_shape(n)
        out.append(spec((batch, *s) if batch else s))
    return out


def kv_specs(cfg: ModelConfig, b: int):
    shape = (cfg.n_layers, b, cfg.n_heads, cfg.max_seq_len, cfg.head_dim)
    return spec(shape), spec(shape)


def bitdelta_specs(cfg: ModelConfig, b: int, levels: int = 1):
    """Decode-ABI arg specs. ``levels > 1`` inserts the mask-level axis
    (`decode_bitdelta_l{L}`): bits [B, L, N, M/8], scales
    [B, L, n_linears]."""
    base = [spec(cfg.linear_shape(n)) for n in cfg.linear_names()]
    if levels > 1:
        bits = [spec((b, levels, *cfg.packed_shape(n)), jnp.uint8)
                for n in cfg.linear_names()]
        scales = spec((b, levels, len(cfg.linear_names())))
    else:
        bits = [spec((b, *cfg.packed_shape(n)), jnp.uint8)
                for n in cfg.linear_names()]
        scales = spec((b, len(cfg.linear_names())))
    extras = [spec((b, *cfg.param_shape(n))) for n in nonlinear_names(cfg)]
    return base, bits, scales, extras


def lora_specs(cfg: ModelConfig, b: int, rank: int):
    base = [spec(cfg.linear_shape(n)) for n in cfg.linear_names()]
    a = [spec((b, rank, cfg.linear_shape(n)[1])) for n in cfg.linear_names()]
    bm = [spec((b, cfg.linear_shape(n)[0], rank)) for n in cfg.linear_names()]
    extras = [spec((b, *cfg.param_shape(n))) for n in nonlinear_names(cfg)]
    return base, a, bm, extras


def export_executables(cfg: ModelConfig, hlo_dir: str, *, full: bool,
                       lora_rank: int, eval_batch: int, eval_len: int,
                       prefill_len: int, decode_batches, quick: bool) -> dict:
    """Lower every executable for one model size. Returns manifest entries."""
    os.makedirs(hlo_dir, exist_ok=True)
    exes = {}

    def path(name):
        return os.path.join(hlo_dir, f"{cfg.name}.{name}.hlo.txt")

    # --- logits forward (eval harness / likelihood scoring) ---------------
    for b in ([1, eval_batch] if full else [eval_batch]):
        name = f"logits_fwd_b{b}_t{eval_len}"
        exes[name] = export_hlo(
            lambda *a: (forward_logits(cfg, dict(zip(cfg.param_names(),
                                                     a[:-1])), a[-1]),),
            [*dense_param_specs(cfg), spec((b, eval_len), jnp.int32)],
            path(name), f"{cfg.name}.{name}")
        exes[name].update(kind="logits_fwd", batch=b, seq=eval_len)

    if not full:
        return exes

    # --- bitdelta logits (serving-path cross-check + Table-1-style eval) --
    b = 1
    base_s, bits_s, scales_s, extras_s = bitdelta_specs(cfg, b)
    name = f"logits_bitdelta_b{b}_t{eval_len}"
    nb, nl = len(base_s), len(cfg.linear_names())

    def logits_bd_fn(*a):
        base = list(a[:nb])
        bits = list(a[nb:nb + nl])
        scales = a[nb + nl]
        nx = len(nonlinear_names(cfg))
        extras = list(a[nb + nl + 1: nb + nl + 1 + nx])
        tokens = a[-1]
        return (logits_bitdelta(cfg, base, bits, scales, extras, tokens),)

    exes[name] = export_hlo(
        logits_bd_fn,
        [*base_s, *bits_s, scales_s, *extras_s,
         spec((b, eval_len), jnp.int32)],
        path(name), f"{cfg.name}.{name}")
    exes[name].update(kind="logits_bitdelta", batch=b, seq=eval_len)

    # --- dense prefill (B=1) ----------------------------------------------
    name = f"prefill_t{prefill_len}"
    exes[name] = export_hlo(
        lambda *a: prefill(cfg, dict(zip(cfg.param_names(), a[:-3])),
                           a[-3], a[-2], a[-1]),
        [*dense_param_specs(cfg), spec((1, prefill_len), jnp.int32),
         spec((), jnp.int32), spec((), jnp.float32)],
        path(name), f"{cfg.name}.{name}")
    exes[name].update(kind="prefill", batch=1, seq=prefill_len)

    # --- decode steps, all modes -------------------------------------------
    # every decode export is untupled: (logits, k, v) come back as three
    # separate device buffers, so the engine can keep K/V device-resident
    # and feed them straight into the next step
    for b in decode_batches["dense"]:
        name = f"decode_dense_b{b}"
        k_s, v_s = kv_specs(cfg, b)
        exes[name] = export_hlo(
            lambda *a: decode_dense(cfg, list(a[:-5]), *a[-5:]),
            [*dense_param_specs(cfg), k_s, v_s, spec((b,), jnp.int32),
             spec((b,), jnp.int32), spec((b,))],
            path(name), f"{cfg.name}.{name}", untuple=True)
        exes[name].update(kind="decode_dense", batch=b)

    for b in decode_batches["naive"]:
        name = f"decode_naive_b{b}"
        k_s, v_s = kv_specs(cfg, b)
        exes[name] = export_hlo(
            lambda *a: decode_naive(cfg, list(a[:-5]), *a[-5:]),
            [*dense_param_specs(cfg, batch=b), k_s, v_s,
             spec((b,), jnp.int32), spec((b,), jnp.int32), spec((b,))],
            path(name), f"{cfg.name}.{name}", untuple=True)
        exes[name].update(kind="decode_naive", batch=b)

    nx = len(nonlinear_names(cfg))
    for b in decode_batches["bitdelta"]:
        name = f"decode_bitdelta_b{b}"
        base_s, bits_s, scales_s, extras_s = bitdelta_specs(cfg, b)
        k_s, v_s = kv_specs(cfg, b)

        def bd_fn(*a, _b=b):
            base = list(a[:nb])
            bits = list(a[nb:nb + nl])
            scales = a[nb + nl]
            extras = list(a[nb + nl + 1: nb + nl + 1 + nx])
            kc, vc, pos, tok, rs = a[-5:]
            return decode_bitdelta(cfg, base, bits, scales, extras,
                                   kc, vc, pos, tok, rs)

        exes[name] = export_hlo(
            bd_fn,
            [*base_s, *bits_s, scales_s, *extras_s, k_s, v_s,
             spec((b,), jnp.int32), spec((b,), jnp.int32), spec((b,))],
            path(name), f"{cfg.name}.{name}", untuple=True)
        exes[name].update(kind="decode_bitdelta", batch=b)

    # multi-level (Fig. 3 fidelity tier) decode: bits carry a level
    # axis summed inside the executable; zero-scale levels are no-ops,
    # so the engine batches mixed tiers by padding to the export's L
    for lv in (2, 4):
        for b in decode_batches.get("bitdelta_multi", []):
            name = f"decode_bitdelta_l{lv}_b{b}"
            base_s, bits_s, scales_s, extras_s = \
                bitdelta_specs(cfg, b, levels=lv)
            k_s, v_s = kv_specs(cfg, b)

            def bdm_fn(*a, _b=b):
                base = list(a[:nb])
                bits = list(a[nb:nb + nl])
                scales = a[nb + nl]
                extras = list(a[nb + nl + 1: nb + nl + 1 + nx])
                kc, vc, pos, tok, rs = a[-5:]
                return decode_bitdelta_multi(cfg, base, bits, scales,
                                             extras, kc, vc, pos, tok,
                                             rs)

            exes[name] = export_hlo(
                bdm_fn,
                [*base_s, *bits_s, scales_s, *extras_s, k_s, v_s,
                 spec((b,), jnp.int32), spec((b,), jnp.int32),
                 spec((b,))],
                path(name), f"{cfg.name}.{name}", untuple=True)
            exes[name].update(kind=f"decode_bitdelta_l{lv}", batch=b,
                              levels=lv)

    for b in decode_batches["lora"]:
        name = f"decode_lora_b{b}"
        base_s, a_s, bm_s, extras_s = lora_specs(cfg, b, lora_rank)
        k_s, v_s = kv_specs(cfg, b)

        def lora_fn(*a, _b=b):
            base = list(a[:nb])
            af = list(a[nb:nb + nl])
            bf = list(a[nb + nl:nb + 2 * nl])
            extras = list(a[nb + 2 * nl: nb + 2 * nl + nx])
            kc, vc, pos, tok, rs = a[-5:]
            return decode_lora(cfg, base, af, bf, extras, kc, vc, pos,
                               tok, rs)

        exes[name] = export_hlo(
            lora_fn,
            [*base_s, *a_s, *bm_s, *extras_s, k_s, v_s,
             spec((b,), jnp.int32), spec((b,), jnp.int32), spec((b,))],
            path(name), f"{cfg.name}.{name}", untuple=True)
        exes[name].update(kind="decode_lora", batch=b, rank=lora_rank)

    # --- KV row extract (device-resident decode download path) -------------
    # pulls each slot's freshly written KV row out of the device-resident
    # cache so the engine downloads (B, L, H, hd) per step instead of the
    # full (L, B, H, S, hd) pair. One export per decode batch width.
    all_widths = sorted({b for widths in decode_batches.values()
                         for b in widths})
    for b in all_widths:
        name = f"kv_row_extract_b{b}"
        k_s, v_s = kv_specs(cfg, b)

        def row_fn(k, v, pos):
            idx = pos.reshape(1, -1, 1, 1, 1)
            rk = jnp.take_along_axis(k, idx, axis=3)[:, :, :, 0, :]
            rv = jnp.take_along_axis(v, idx, axis=3)[:, :, :, 0, :]
            # (L, B, H, hd) -> (B, L, H, hd): per-slot rows contiguous
            return (jnp.transpose(rk, (1, 0, 2, 3)),
                    jnp.transpose(rv, (1, 0, 2, 3)))

        exes[name] = export_hlo(
            row_fn, [k_s, v_s, spec((b,), jnp.int32)],
            path(name), f"{cfg.name}.{name}", untuple=True)
        exes[name].update(kind="kv_row_extract", batch=b)

    return exes


# ---------------------------------------------------------------------------
# Pipeline
# ---------------------------------------------------------------------------


def svd_to_kernel_abi(factors):
    """svd_baseline gives (A [N,r], B [r,M]); kernel ABI wants
    (a_down [r,M], b_up [N,r]) with delta = b_up @ a_down."""
    return {n: (b, a) for n, (a, b) in factors.items()}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="small CI build: fewer steps, sim-s only")
    ap.add_argument("--skip-hlo", action="store_true")
    ap.add_argument("--resume", action="store_true",
                    help="reuse models already trained in out-dir")
    args = ap.parse_args()

    out = os.path.abspath(args.out_dir)
    for sub in ("models", "deltas", "hlo", "eval"):
        os.makedirs(os.path.join(out, sub), exist_ok=True)

    t_start = time.time()
    manifest: dict = {"version": 1, "configs": {}, "models": {},
                      "tenants": {}, "executables": {}, "evals": [],
                      "lora_rank": 16}

    # ---- 1. world + data ---------------------------------------------------
    world = D.World(seed=0)
    corpus = D.make_pretrain_corpus(world, n_chars=150_000 if args.quick
                                    else 400_000)
    D.write_evals(world, os.path.join(out, "eval"))
    manifest["evals"] = sorted(os.listdir(os.path.join(out, "eval")))

    tcfg = TrainConfig()
    dcfg = DistillConfig()
    if args.quick:
        tcfg = dataclasses.replace(tcfg, pretrain_steps=60,
                                   finetune_steps=30)
        dcfg = dataclasses.replace(dcfg, steps=30, n_samples=64)
    calib = bd.calibration_batches(corpus, dcfg)

    sizes = ["sim-s"] if args.quick else ["sim-s", "sim-m"]

    chat_docs = D.make_chat_dataset(world)
    # math tenant: heavier dataset + a replay slice of generic facts so
    # the fine-tune gains arithmetic without catastrophic forgetting
    # (standard SFT data mixing)
    math_docs = (D.make_math_dataset(n=8000)
                 + D.make_chat_dataset(world, n=800, seed=77))
    prefs = D.make_preference_dataset(world)

    def save_model(name, cfg, params):
        p = os.path.join(out, "models", f"{name}.bdw")
        write_model(p, cfg, params)
        manifest["models"][name] = {"file": f"models/{name}.bdw",
                                    "config": cfg.name}

    def cached(name, cfg, make):
        """Train (or reload with --resume) a model, registering it."""
        p = os.path.join(out, "models", f"{name}.bdw")
        if args.resume and os.path.exists(p):
            print(f"[aot] resume: loading {name}", flush=True)
            params = {k: jnp.asarray(v) for k, v in read_bdw(p).items()}
            manifest["models"][name] = {"file": f"models/{name}.bdw",
                                        "config": cfg.name}
            return params
        params = make()
        save_model(name, cfg, params)
        return params

    for size in sizes:
        cfg = CONFIGS[size]
        manifest["configs"][size] = cfg.to_json()

        # ---- 2. pretrain + fine-tune ---------------------------------------
        base = cached(f"{size}-base", cfg,
                      lambda: T.pretrain(cfg, tcfg, corpus))

        tenants: dict = {}
        tenants[f"{size}-chat"] = dict(
            kind="sft", rope_scale=1.0,
            params=cached(f"{size}-chat", cfg,
                          lambda: T.finetune_full(cfg, tcfg, base, chat_docs,
                                                  f"ft/{size}-chat")))
        tenants[f"{size}-math"] = dict(
            kind="sft", rope_scale=1.0,
            params=cached(f"{size}-math", cfg,
                          lambda: T.finetune_full(
                              cfg, tcfg, base, math_docs,
                              f"ft/{size}-math",
                              steps=None if args.quick
                              else tcfg.finetune_steps * 4)))
        if size == "sim-s":
            tenants[f"{size}-rlhf"] = dict(
                kind="rlhf", rope_scale=1.0,
                params=cached(f"{size}-rlhf", cfg,
                              lambda: T.finetune_rlhf(cfg, tcfg, base, prefs,
                                                      f"ft/{size}-rlhf")))
            # context extension: position interpolation at 0.5 over longer
            # windows (the Vicuna-16k analog)
            tenants[f"{size}-chat-ext"] = dict(
                kind="rope", rope_scale=0.5,
                params=cached(f"{size}-chat-ext", cfg,
                              lambda: T.finetune_full(
                                  cfg, tcfg, base, chat_docs,
                                  f"ft/{size}-chat-ext", rope_scale=0.5,
                                  seq_len=min(192, cfg.max_seq_len))))
            tenants[f"{size}-lora"] = dict(
                kind="lora-ft", rope_scale=1.0,
                params=cached(f"{size}-lora", cfg,
                              lambda: T.finetune_lora(cfg, tcfg, base,
                                                      chat_docs,
                                                      f"ft/{size}-lora",
                                                      rank=16)))

        # ---- 3+4. compress + serialize --------------------------------------
        for tname, t in tenants.items():
            dpath = f"deltas/{tname}.bdd"
            dpath0 = f"deltas/{tname}.initial.bdd"
            done = (args.resume
                    and os.path.exists(os.path.join(out, dpath))
                    and os.path.exists(os.path.join(out, dpath0)))
            if done:
                print(f"[aot] resume: delta {tname} exists", flush=True)
            else:
                bits, scales0 = bd.quantize_deltas(cfg, base, t["params"])
                scales = bd.distill_scales(cfg, base, t["params"], bits,
                                           scales0, calib, dcfg,
                                           rope_scale=t["rope_scale"],
                                           tag=f"distill/{tname}")
                extras = {n: np.asarray(t["params"][n], np.float32)
                          for n in nonlinear_names(cfg)}
                write_delta(os.path.join(out, dpath), cfg,
                            [(bits, scales)], extras)
                write_delta(os.path.join(out, dpath0), cfg,
                            [(bits, scales0)], extras)
            manifest["tenants"][tname] = {
                "config": size, "kind": t["kind"],
                "rope_scale": t["rope_scale"],
                "finetune": f"models/{tname}.bdw",
                "delta": dpath, "delta_initial": dpath0,
            }
            size_info = bd.delta_size_bytes(cfg)
            manifest["tenants"][tname]["compression"] = size_info

        # sim-s gets the full ablation battery
        if size == "sim-s":
            chat = tenants[f"{size}-chat"]["params"]

            # ---- SVD baselines (Table 1): r=16 (common) and the
            # memory-equivalent rank d/32 (paper's r=128 at d=4096) --------
            for rank, label in [(16, "r16"),
                                (max(2, cfg.d_model // 32), "req")]:
                lp_done = os.path.join(
                    out, f"deltas/{size}-chat.svd-{label}.distilled.bdw")
                if args.resume and os.path.exists(lp_done):
                    print(f"[aot] resume: svd-{label} exists", flush=True)
                else:
                    fac0 = S.svd_compress(cfg, base, chat, rank)
                    fac = S.distill_factors(
                        cfg, base, chat, fac0, calib, dcfg,
                        tag=f"svd-{label}/{size}",
                        steps=(dcfg.steps // 2 if not args.quick else 10))
                    extras = {n: np.asarray(chat[n], np.float32)
                              for n in nonlinear_names(cfg)}
                    for tag2, f in [("initial", fac0), ("distilled", fac)]:
                        lp = f"deltas/{size}-chat.svd-{label}.{tag2}.bdw"
                        write_lora(os.path.join(out, lp), cfg,
                                   svd_to_kernel_abi(f), extras)
                manifest["tenants"][f"{size}-chat"][f"svd_{label}"] = {
                    "rank": min(rank, cfg.d_model),
                    "initial": f"deltas/{size}-chat.svd-{label}.initial.bdw",
                    "distilled":
                        f"deltas/{size}-chat.svd-{label}.distilled.bdw",
                }

            # ---- iterative multi-mask deltas (Fig. 3 / Table 9) ------------
            # chat drives the ablation table; math gets fidelity files
            # too so the serving layer can batch tenants at different
            # tiers (--tenant-levels mixes {1, 2, 4} in one decode)
            levels = 4 if args.quick else 8
            for ft_name in (f"{size}-chat", f"{size}-math"):
                ft = tenants[ft_name]["params"]
                masks = bd.iterative_bitdelta(cfg, base, ft, levels)
                extras = {n: np.asarray(ft[n], np.float32)
                          for n in nonlinear_names(cfg)}
                fidelity = {}
                for k in range(1, levels + 1):
                    fp = f"deltas/{ft_name}.fidelity{k}.bdd"
                    write_delta(os.path.join(out, fp), cfg, masks[:k],
                                extras)
                    fidelity[str(k)] = fp
                manifest["tenants"][ft_name]["fidelity"] = fidelity

            # ---- quantized bases (Table 6) ---------------------------------
            hess = None
            qbases = {}
            for method in ("rtn8", "gptq4", "quip2"):
                qname = f"{size}-base-{method}"
                dp = f"deltas/{size}-chat.on-{method}.bdd"
                if args.resume and os.path.exists(os.path.join(out, dp)):
                    print(f"[aot] resume: quant {method} exists", flush=True)
                    for mn in (qname, f"{size}-chat-{method}"):
                        manifest["models"][mn] = {
                            "file": f"models/{mn}.bdw", "config": cfg.name}
                else:
                    if hess is None and method == "gptq4":
                        hess = Q.collect_hessians(cfg, base, calib)
                    qb = Q.quantize_base(cfg, base, method, hessians=hess)
                    save_model(qname, cfg, qb)
                    # quantized *fine-tune* = Table 6 "Baseline" rows
                    qf = Q.quantize_base(cfg, chat, method, hessians=hess)
                    save_model(f"{size}-chat-{method}", cfg, qf)
                    # re-quantize + re-distill the delta on the new base
                    bits, scales0 = bd.quantize_deltas(cfg, qb, chat)
                    scales = bd.distill_scales(
                        cfg, qb, chat, bits, scales0, calib, dcfg,
                        tag=f"distill/{qname}",
                        steps=(dcfg.steps // 2 if not args.quick else 10))
                    extras = {n: np.asarray(chat[n], np.float32)
                              for n in nonlinear_names(cfg)}
                    write_delta(os.path.join(out, dp), cfg,
                                [(bits, scales)], extras)
                qbases[method] = {"base": f"models/{qname}.bdw",
                                  "chat_quantized":
                                      f"models/{size}-chat-{method}.bdw",
                                  "delta": dp}
            manifest["quantized_bases"] = qbases

        # ---- 5. HLO exports --------------------------------------------------
        if not args.skip_hlo:
            decode_batches = {
                "dense": [1, 8],
                "naive": [1, 2, 4, 8],
                "bitdelta": [1, 2, 4, 8, 16],
                "bitdelta_multi": [1, 2, 4, 8],
                "lora": [1, 2, 4, 8, 16],
            }
            if args.quick:
                decode_batches = {"dense": [1], "naive": [1, 2],
                                  "bitdelta": [1, 2],
                                  "bitdelta_multi": [1, 2],
                                  "lora": [1, 2]}
            exes = export_executables(
                cfg, os.path.join(out, "hlo"),
                full=(size == "sim-s"), lora_rank=16,
                eval_batch=8, eval_len=96, prefill_len=64,
                decode_batches=decode_batches, quick=args.quick)
            for name, e in exes.items():
                e["path"] = f"hlo/{cfg.name}.{name}.hlo.txt"
                manifest["executables"][f"{cfg.name}.{name}"] = \
                    {**e, "config": size}

    manifest["build_seconds"] = round(time.time() - t_start, 1)
    manifest["quick"] = args.quick
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] DONE in {manifest['build_seconds']}s -> {out}",
          flush=True)


if __name__ == "__main__":
    main()
