"""Base-model quantizers for Table 6: BitDelta applied *on top of* a
quantized base model.

The paper stacks its 1-bit delta on FP16 / INT8-RTN / GPTQ(4-bit) /
QuIP#(2-bit) bases; since all of those run with 16-bit activations, only
the base *weight values* change — the delta and its scales stay high
precision. We implement the same three algorithm families at reduced
engineering scope (DESIGN.md §3 substitutions):

* ``rtn``   — per-output-channel symmetric round-to-nearest at any bit
              width (8 for the INT8 row).
* ``gptq``  — GPTQ-lite: per-channel RTN grids plus the second-order
              column-by-column error propagation of Frantar et al. (2022),
              using a Hessian proxy H = XᵀX accumulated from calibration
              activations (4-bit row).
* ``quip``  — QuIP-lite: 2-bit RTN after a random-sign Hadamard rotation
              (incoherence processing), rotated back after quantization
              (2-bit row).

All three return *dequantized dense weights*, which is numerically exactly
what the paper's quality rows measure.
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from .config import ModelConfig
from .model import Params


def rtn_quantize_matrix(w: np.ndarray, bits: int) -> np.ndarray:
    """Per-row (output channel) symmetric RTN; returns dequantized f32."""
    qmax = 2.0 ** (bits - 1) - 1
    scale = np.maximum(np.abs(w).max(axis=1, keepdims=True), 1e-12) / qmax
    q = np.clip(np.round(w / scale), -qmax - 1, qmax)
    return (q * scale).astype(np.float32)


def _hadamard(n: int) -> np.ndarray:
    """Sylvester Hadamard matrix H_n / √n (n must be a power of two)."""
    assert n & (n - 1) == 0, f"{n} not a power of two"
    h = np.array([[1.0]])
    while h.shape[0] < n:
        h = np.block([[h, h], [h, -h]])
    return (h / np.sqrt(n)).astype(np.float32)


def quip_quantize_matrix(w: np.ndarray, bits: int = 2,
                         seed: int = 0) -> np.ndarray:
    """QuIP-lite: random-sign Hadamard incoherence rotation on the input
    dimension, RTN in the rotated basis, rotate back.

    Input dims that aren't powers of two are zero-padded up (the rotation
    is orthogonal either way)."""
    n, m = w.shape
    m2 = 1 << (m - 1).bit_length()
    rng = np.random.default_rng(seed)
    signs = rng.choice([-1.0, 1.0], size=m2).astype(np.float32)
    h = _hadamard(m2) * signs[None, :]        # orthogonal: H·diag(s)
    wp = np.zeros((n, m2), np.float32)
    wp[:, :m] = w
    rotated = wp @ h
    q = rtn_quantize_matrix(rotated, bits)
    back = q @ h.T
    return back[:, :m].astype(np.float32)


def collect_hessians(cfg: ModelConfig, params: Params,
                     calib_tokens: np.ndarray,
                     n_batches: int = 8) -> Dict[str, np.ndarray]:
    """Accumulate H = XᵀX per linear from calibration activations by
    running the real forward and hooking each linear's input."""
    import jax
    import jax.numpy as jnp

    from .model import DenseWeights, rmsnorm, apply_rope, rope_angles

    hess: Dict[str, np.ndarray] = {}

    def record(name, x):
        x2 = np.asarray(x, np.float32).reshape(-1, x.shape[-1])
        h = x2.T @ x2
        hess[name] = hess.get(name, 0.0) + h

    w = DenseWeights(cfg, params)
    for bi in range(n_batches):
        tokens = jnp.asarray(calib_tokens[bi * 4:(bi + 1) * 4])
        b, t = tokens.shape
        x = params["tok_embed"][tokens]
        cos, sin = rope_angles(cfg, jnp.arange(t, dtype=jnp.float32))
        mask = jnp.tril(jnp.ones((t, t), bool))
        import jax.nn as jnn
        for layer in range(cfg.n_layers):
            pre = f"layers.{layer}."
            h = rmsnorm(x, params[pre + "attn_norm"], cfg.norm_eps)
            record(pre + "wq", h); record(pre + "wk", h); record(pre + "wv", h)
            q = (h @ params[pre + "wq"].T).reshape(b, t, cfg.n_heads, cfg.head_dim)
            k = (h @ params[pre + "wk"].T).reshape(b, t, cfg.n_heads, cfg.head_dim)
            v = (h @ params[pre + "wv"].T).reshape(b, t, cfg.n_heads, cfg.head_dim)
            q = apply_rope(q, cos[None, :, None, :], sin[None, :, None, :])
            k = apply_rope(k, cos[None, :, None, :], sin[None, :, None, :])
            scores = jnp.einsum("bthd,bshd->bhts", q, k) * (cfg.head_dim ** -0.5)
            scores = jnp.where(mask[None, None], scores, -1e30)
            attn = jnn.softmax(scores, axis=-1)
            o = jnp.einsum("bhts,bshd->bthd", attn, v).reshape(b, t, cfg.d_model)
            record(pre + "wo", o)
            x = x + o @ params[pre + "wo"].T
            h = rmsnorm(x, params[pre + "mlp_norm"], cfg.norm_eps)
            record(pre + "w_gate", h); record(pre + "w_up", h)
            gate = jnn.silu(h @ params[pre + "w_gate"].T)
            up = h @ params[pre + "w_up"].T
            record(pre + "w_down", gate * up)
            x = x + (gate * up) @ params[pre + "w_down"].T
    return hess


def gptq_quantize_matrix(w: np.ndarray, hessian: np.ndarray,
                         bits: int = 4, damp: float = 0.01) -> np.ndarray:
    """GPTQ-lite: quantize columns left-to-right, propagating the rounding
    error through the inverse-Hessian Cholesky factors (Frantar et al.
    2022, without the lazy-batch blocking)."""
    n, m = w.shape
    h = hessian.astype(np.float64).copy()
    mean_diag = np.mean(np.diag(h))
    h[np.diag_indices(m)] += damp * max(mean_diag, 1e-8)

    qmax = 2.0 ** (bits - 1) - 1
    scale = np.maximum(np.abs(w).max(axis=1, keepdims=True), 1e-12) / qmax

    hinv = np.linalg.inv(h)
    # Cholesky of the inverse, upper-triangular form as in the paper.
    l = np.linalg.cholesky(hinv)
    hinv_u = l.T

    wq = w.astype(np.float64).copy()
    out = np.zeros_like(wq)
    for j in range(m):
        col = wq[:, j]
        q = np.clip(np.round(col / scale[:, 0]), -qmax - 1, qmax)
        dq = q * scale[:, 0]
        out[:, j] = dq
        err = (col - dq) / hinv_u[j, j]
        if j + 1 < m:
            wq[:, j + 1:] -= np.outer(err, hinv_u[j, j + 1:])
    return out.astype(np.float32)


def quantize_base(cfg: ModelConfig, base: Params, method: str,
                  hessians: Dict[str, np.ndarray] | None = None) -> Params:
    """Quantize the base model's transformer-block linears (embeddings,
    norms, and head stay fp — mirroring the paper, whose quantizers also
    only touch the linears)."""
    out = {n: np.asarray(v, np.float32) for n, v in base.items()}
    for name in cfg.linear_names():
        w = np.asarray(base[name], np.float32)
        if method == "rtn8":
            out[name] = rtn_quantize_matrix(w, 8)
        elif method == "gptq4":
            assert hessians is not None, "gptq needs calibration hessians"
            out[name] = gptq_quantize_matrix(w, hessians[name], bits=4)
        elif method == "quip2":
            out[name] = quip_quantize_matrix(w, bits=2,
                                             seed=hash(name) % (2 ** 31))
        else:
            raise ValueError(f"unknown method {method}")
    return out
