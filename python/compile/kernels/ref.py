"""Pure-jnp reference oracles for the L1 Pallas kernels.

These are the ground truth the kernels are tested against (pytest +
hypothesis), and they double as the documentation of the bit-packing ABI
shared with rust (`rust/src/delta/packing.rs`):

* Sign bits are packed **along the input dimension (columns)**, LSB-first:
  byte ``k`` of a row holds columns ``8k .. 8k+7``; bit ``j`` set means the
  delta at column ``8k+j`` is **positive** (+1), clear means non-positive
  (-1). This matches the paper's Sign() (Eq. 2): zero maps to -1.
* A row of ``M`` columns therefore occupies ``M/8`` bytes; ``M`` must be a
  multiple of 8 (all our model dims are).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def pack_signs(delta) -> jnp.ndarray:
    """Pack the sign pattern of ``delta`` ([..., M] float) into u8
    [..., M/8], bit set iff the entry is strictly positive."""
    delta = jnp.asarray(delta)
    m = delta.shape[-1]
    assert m % 8 == 0, f"last dim {m} not a multiple of 8"
    bits = (delta > 0).astype(jnp.uint8)
    bits = bits.reshape(*delta.shape[:-1], m // 8, 8)
    shifts = jnp.arange(8, dtype=jnp.uint8)
    return jnp.sum(bits << shifts, axis=-1).astype(jnp.uint8)


def unpack_signs(packed, m: int) -> jnp.ndarray:
    """Inverse of :func:`pack_signs`: u8 [..., M/8] -> float32 ±1 [..., M]."""
    packed = jnp.asarray(packed)
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits = (packed[..., None] >> shifts) & jnp.uint8(1)
    signs = bits.astype(jnp.float32) * 2.0 - 1.0
    return signs.reshape(*packed.shape[:-1], m)


def binary_gemm_ref(bits, scale, x) -> jnp.ndarray:
    """Reference for the batched W_INT1·A_FP16-analog kernel (Eq. 6 delta
    term)::

        y[b] = scale[b] * (x[b] @ Sign(Δ_b)^T)

    Args:
      bits:  u8  [B, N, M/8]  packed sign matrices, one per tenant.
      scale: f32 [B]          per-tenant scale factor α.
      x:     f32 [B, L, M]    activations (L = 1 when decoding).

    Returns:
      f32 [B, L, N].
    """
    b, n, mp = bits.shape
    m = mp * 8
    signs = unpack_signs(bits, m)            # [B, N, M]
    y = jnp.einsum("blm,bnm->bln", x, signs)
    return y * scale[:, None, None]


def lora_gemm_ref(a, bmat, x) -> jnp.ndarray:
    """Reference for the batched low-rank (S-LoRA baseline) kernel::

        y[b] = (x[b] @ A_b^T) @ B_b^T

    Args:
      a:    f32 [B, r, M]   down-projection factors.
      bmat: f32 [B, N, r]   up-projection factors.
      x:    f32 [B, L, M]   activations.

    Returns:
      f32 [B, L, N].
    """
    h = jnp.einsum("blm,brm->blr", x, a)
    return jnp.einsum("blr,bnr->bln", h, bmat)


def quantize_ref(delta) -> tuple:
    """BitDelta quantization (Eq. 1-4): returns (packed bits, alpha)."""
    delta = jnp.asarray(delta, jnp.float32)
    alpha = jnp.mean(jnp.abs(delta))
    return pack_signs(delta), alpha


def dequantize_ref(bits, alpha, m: int) -> jnp.ndarray:
    """Δ̂ = α · Sign(Δ) reconstructed from packed form."""
    return alpha * unpack_signs(bits, m)


def pack_signs_np(delta: np.ndarray) -> np.ndarray:
    """NumPy twin of :func:`pack_signs` (used by serialization, no jax)."""
    m = delta.shape[-1]
    assert m % 8 == 0
    bits = (delta > 0).astype(np.uint8).reshape(*delta.shape[:-1], m // 8, 8)
    return np.sum(bits << np.arange(8, dtype=np.uint8), axis=-1).astype(np.uint8)


def unpack_signs_np(packed: np.ndarray, m: int) -> np.ndarray:
    bits = (packed[..., None] >> np.arange(8, dtype=np.uint8)) & 1
    return (bits.astype(np.float32) * 2.0 - 1.0).reshape(*packed.shape[:-1], m)
