"""L1 Pallas kernel: batched low-rank delta GEMM (the S-LoRA baseline).

Computes, for a batch of B tenants each carrying a rank-r adapter,

    y[b] = ( x[b] @ A_b^T ) @ B_b^T

This is the kernel BitDelta is compared against in Fig. 4 / Fig. 6 (paper
§4.3): S-LoRA/Punica batch the low-rank delta product across tenants the
same way BitDelta batches the 1-bit delta product. At r = 128 and
N = M = 4096 the adapter's memory footprint equals the packed 1-bit delta
(2·r·N·2 bytes fp16 = N·M/8 bytes), which is why the paper uses r=128 for
the memory-equivalent comparison.

Two matmuls per tenant, staged through a rank-r intermediate held in VMEM:
per grid step the working set is A-tile (r·BM·4) + x (L·BM·4) + h (L·r·4)
+ B-tile (BN·r·4) + acc (L·BN·4) — small for r ≤ 128.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lora_kernel(a_ref, b_ref, x_ref, o_ref):
    """One grid step: full rank-r product for one tenant.

    The ranks we serve (r ≤ 128) keep both factors comfortably in VMEM, so
    the grid is just (B,) — one step per tenant, mirroring how S-LoRA's
    BGMV kernel assigns adapters to thread blocks.
    """
    a = a_ref[0]          # [r, M]
    b = b_ref[0]          # [N, r]
    x = x_ref[0]          # [L, M]
    h = jax.lax.dot_general(
        x, a, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)              # [L, r]
    o_ref[0] = jax.lax.dot_general(
        h, b, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)              # [L, N]


def lora_gemm(a, bmat, x) -> jnp.ndarray:
    """Batched low-rank delta GEMM via Pallas.

    Args:
      a:    f32 [B, r, M]  down-projection factors.
      bmat: f32 [B, N, r]  up-projection factors.
      x:    f32 [B, L, M]  activations.

    Returns:
      f32 [B, L, N].
    """
    b, r, m = a.shape
    _, n, r2 = bmat.shape
    _, l, mx = x.shape
    assert r == r2 and mx == m

    return pl.pallas_call(
        _lora_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, r, m), lambda bi: (bi, 0, 0)),
            pl.BlockSpec((1, n, r), lambda bi: (bi, 0, 0)),
            pl.BlockSpec((1, l, m), lambda bi: (bi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, l, n), lambda bi: (bi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, l, n), jnp.float32),
        interpret=True,
    )(a, bmat, x)
