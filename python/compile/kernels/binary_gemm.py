"""L1 Pallas kernel: batched 1-bit-delta GEMM (the W_INT1·A_FP16 analog).

This is the hot-spot of BitDelta's Eq. 6: for a batch of B tenants, compute

    y[b] = alpha[b] * ( x[b] @ Sign(Delta_b)^T )

where ``Sign(Delta_b)`` is stored *packed*, one bit per weight, and only
unpacked inside the kernel — the fused dequant-GEMM trick that makes the
1-bit delta pay off as memory traffic, not just storage.

TPU mapping (DESIGN.md §4): the CUDA/BitBLAS kernel streams packed weights
from HBM into shared memory and fuses unpack into the MMA prologue. Here the
BlockSpec schedule streams ``(BN x BM/8)``-byte tiles of the packed matrix
HBM->VMEM, the kernel broadcasts each byte against an 8-lane shift iota to
materialise ±1 values **in VMEM only**, and feeds them straight to the dot
unit. Per grid step the working set is

    bits tile  BN * BM/8  bytes
    x tile     L  * BM * 4 bytes
    acc tile   L  * BN * 4 bytes

≈ 19 KB at (BN, BM) = (256, 512), far below VMEM, leaving room for the
compiler to double-buffer the bits stream.

``interpret=True`` always: real-TPU lowering emits a Mosaic custom-call the
CPU PJRT plugin cannot execute (see /opt/xla-example/README.md). Wallclock
claims for Fig. 4 come from the rust CPU kernels; this kernel carries the
numerics and the structure.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. BN divides every linear's output dim in our configs;
# BM divides every input dim. Both are clamped to the actual dims at call
# time so small test shapes work unchanged.
BLOCK_N = 256
BLOCK_M = 512


def _binary_gemm_kernel(scale_ref, bits_ref, x_ref, o_ref, *, bm: int):
    """One grid step: o[L, BN] (+)= alpha * x[L, BM] @ signs[BN, BM]^T.

    Grid is (B, N/BN, M/BM) with the M (reduction) axis innermost, so the
    accumulator tile stays resident while packed-bit tiles stream through.
    """
    k = pl.program_id(2)

    # Unpack u8 [BN, BM/8] -> ±1 f32 [BN, BM] entirely in VMEM.
    bits = bits_ref[0]                                   # [BN, BM/8] u8
    shifts = jnp.arange(8, dtype=jnp.uint8)
    expanded = (bits[:, :, None] >> shifts) & jnp.uint8(1)
    signs = expanded.astype(jnp.float32).reshape(bits.shape[0], bm) * 2.0 - 1.0

    x = x_ref[0]                                         # [L, BM] f32
    partial = jax.lax.dot_general(
        x, signs,
        dimension_numbers=(((1,), (1,)), ((), ())),      # x @ signs^T
        preferred_element_type=jnp.float32,
    )                                                    # [L, BN]
    partial = partial * scale_ref[0]

    @pl.when(k == 0)
    def _init():
        o_ref[0] = partial

    @pl.when(k > 0)
    def _acc():
        o_ref[0] += partial


def _largest_divisor(dim: int, target: int, multiple: int) -> int:
    """Largest divisor of ``dim`` that is ≤ target and a multiple of
    ``multiple`` (model dims like d_ff=344 are not powers of two)."""
    best = dim
    for cand in range(min(target, dim), 0, -1):
        if dim % cand == 0 and cand % multiple == 0:
            best = cand
            break
    return best


def binary_gemm(bits, scale, x, *, block_n: int = BLOCK_N,
                block_m: int = BLOCK_M) -> jnp.ndarray:
    """Batched 1-bit delta GEMM via Pallas.

    Args:
      bits:  u8  [B, N, M/8]  packed per-tenant sign matrices.
      scale: f32 [B]          per-tenant BitDelta scale α.
      x:     f32 [B, L, M]    activations (L=1 in decode).
      block_n, block_m: tile sizes (clamped to N, M).

    Returns:
      f32 [B, L, N] — the delta term of Eq. 6 for every tenant in the batch.
    """
    b, n, mp = bits.shape
    m = mp * 8
    _, l, mx = x.shape
    assert mx == m, f"x last dim {mx} != unpacked bits dim {m}"
    assert scale.shape == (b,)

    bn = _largest_divisor(n, block_n, 1)
    bm = _largest_divisor(m, block_m, 8)
    assert n % bn == 0 and m % bm == 0 and bm % 8 == 0, (n, m, bn, bm)
    grid = (b, n // bn, m // bm)

    kernel = functools.partial(_binary_gemm_kernel, bm=bm)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bi, ni, ki: (bi,)),                # scale
            pl.BlockSpec((1, bn, bm // 8), lambda bi, ni, ki: (bi, ni, ki)),
            pl.BlockSpec((1, l, bm), lambda bi, ni, ki: (bi, 0, ki)),    # x
        ],
        out_specs=pl.BlockSpec((1, l, bn), lambda bi, ni, ki: (bi, 0, ni)),
        out_shape=jax.ShapeDtypeStruct((b, l, n), jnp.float32),
        interpret=True,
    )(scale, bits, x)


def vmem_footprint(block_n: int, block_m: int, l: int = 1) -> dict:
    """Static VMEM accounting for one grid step (used by tests and the
    §Perf structural analysis — interpret mode has no real VMEM)."""
    bits = block_n * block_m // 8
    x = l * block_m * 4
    acc = l * block_n * 4
    signs = block_n * block_m * 4     # transient unpacked tile
    return {
        "bits_bytes": bits,
        "x_bytes": x,
        "acc_bytes": acc,
        "signs_bytes": signs,
        "resident_bytes": bits + x + acc,
        "peak_bytes": bits + x + acc + signs,
    }


def hbm_bytes_per_call(b: int, n: int, m: int, l: int = 1,
                       block_m: int = BLOCK_M) -> dict:
    """HBM traffic model for one kernel call vs. the dense-fp16 equivalent —
    the quantity the paper's >10x latency claim rides on."""
    bm = min(block_m, m)
    packed = b * n * m // 8                     # bits stream, read once
    x_reads = b * (m // bm) * 0 + b * l * m * 4 * (n // min(BLOCK_N, n))
    out = b * l * n * 4
    dense_fp16 = b * n * m * 2 + b * l * m * 2 + b * l * n * 2
    return {
        "packed_weight_bytes": packed,
        "activation_bytes": x_reads,
        "output_bytes": out,
        "total": packed + x_reads + out,
        "dense_fp16_total": dense_fp16,
        "weight_traffic_ratio": (n * m * 2) / (n * m / 8),
    }
