"""L2 model tests: forward shapes, decode/prefill consistency across all
four serving modes, RoPE scaling, and KV-cache semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import bitdelta as bd
from compile.config import ModelConfig
from compile.model import (DenseWeights, NaiveWeights, decode_bitdelta,
                           decode_dense, decode_lora, decode_naive,
                           forward_logits, init_params, flatten_params,
                           nonlinear_names, prefill)

TINY = ModelConfig(name="tiny", d_model=32, n_layers=2, n_heads=2,
                   d_ff=64, max_seq_len=48)


@pytest.fixture(scope="module")
def params():
    return init_params(TINY, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def tokens():
    return jnp.asarray(
        np.random.default_rng(0).integers(0, 255, (2, 20), np.int32))


class TestForward:
    def test_logits_shape(self, params, tokens):
        z = forward_logits(TINY, params, tokens)
        assert z.shape == (2, 20, TINY.vocab_size)

    def test_causality(self, params, tokens):
        """Changing a future token must not change past logits."""
        z1 = forward_logits(TINY, params, tokens)
        toks2 = tokens.at[:, 10].set((tokens[:, 10] + 1) % 256)
        z2 = forward_logits(TINY, params, toks2)
        np.testing.assert_allclose(np.asarray(z1[:, :10]),
                                   np.asarray(z2[:, :10]),
                                   rtol=1e-5, atol=1e-5)
        assert not np.allclose(np.asarray(z1[:, 10:]), np.asarray(z2[:, 10:]))

    def test_rope_scale_changes_output(self, params, tokens):
        z1 = forward_logits(TINY, params, tokens, rope_scale=1.0)
        z2 = forward_logits(TINY, params, tokens, rope_scale=0.5)
        assert not np.allclose(np.asarray(z1), np.asarray(z2))


class TestDecodeVsForward:
    """The batched decode step must reproduce the full forward, token by
    token — this is the invariant the whole serving engine rests on."""

    def _decode_seq(self, params, seq, mode="dense"):
        cfg = TINY
        b = 1
        shape = (cfg.n_layers, b, cfg.n_heads, cfg.max_seq_len, cfg.head_dim)
        kc = jnp.zeros(shape)
        vc = jnp.zeros(shape)
        rope = jnp.ones((b,), jnp.float32)
        logits_steps = []
        flat = flatten_params(cfg, params)
        for t, tok in enumerate(seq):
            pos = jnp.array([t], jnp.int32)
            token = jnp.array([tok], jnp.int32)
            z, kc, vc = decode_dense(cfg, flat, kc, vc, pos, token, rope)
            logits_steps.append(np.asarray(z[0]))
        return np.stack(logits_steps)

    def test_dense_decode_matches_forward(self, params):
        seq = list(np.random.default_rng(1).integers(0, 255, 12))
        z_fwd = np.asarray(forward_logits(
            TINY, params, jnp.asarray([seq], jnp.int32))[0])
        z_dec = self._decode_seq(params, seq)
        np.testing.assert_allclose(z_dec, z_fwd, rtol=1e-3, atol=1e-3)

    def test_bitdelta_decode_matches_materialized(self, params):
        """decode_bitdelta ≡ decode_dense on the dequantized weights."""
        cfg = TINY
        rng = np.random.default_rng(2)
        fine = {n: jnp.asarray(np.asarray(w) + 0.01 *
                               rng.standard_normal(w.shape).astype(np.float32))
                for n, w in params.items()}
        bits, scales = bd.quantize_deltas(cfg, params, fine)
        extras = {n: fine[n] for n in nonlinear_names(cfg)}
        from compile.model import materialize_bitdelta
        dense = materialize_bitdelta(cfg, params, bits, scales, extras)

        b = 2
        shape = (cfg.n_layers, b, cfg.n_heads, cfg.max_seq_len, cfg.head_dim)
        kc = jnp.zeros(shape); vc = jnp.zeros(shape)
        kc2 = jnp.zeros(shape); vc2 = jnp.zeros(shape)
        rope = jnp.ones((b,), jnp.float32)
        lin = cfg.linear_names()
        flat_base = [params[n] for n in lin]
        flat_bits = [jnp.asarray(np.stack([bits[n]] * b)) for n in lin]
        sc = jnp.asarray(np.stack([scales] * b))
        flat_extras = [jnp.asarray(np.stack([np.asarray(extras[n])] * b))
                       for n in nonlinear_names(cfg)]
        flat_dense = flatten_params(cfg, dense)

        seq = list(np.random.default_rng(3).integers(0, 255, 6))
        for t, tok in enumerate(seq):
            pos = jnp.full((b,), t, jnp.int32)
            token = jnp.full((b,), tok, jnp.int32)
            z1, kc, vc = decode_bitdelta(cfg, flat_base, flat_bits, sc,
                                         flat_extras, kc, vc, pos, token,
                                         rope)
            z2, kc2, vc2 = decode_dense(cfg, flat_dense, kc2, vc2, pos,
                                        token, rope)
            np.testing.assert_allclose(np.asarray(z1), np.asarray(z2),
                                       rtol=1e-3, atol=1e-3)

    def test_bitdelta_multi_zero_scale_padding_matches_single(self, params):
        """decode_bitdelta_multi with a zero-scale padding level ≡
        decode_bitdelta on the real level — the engine's convention for
        batching tenants at different fidelity tiers."""
        from compile.model import decode_bitdelta_multi
        cfg = TINY
        rng = np.random.default_rng(5)
        fine = {n: jnp.asarray(np.asarray(w) + 0.01 *
                               rng.standard_normal(w.shape).astype(np.float32))
                for n, w in params.items()}
        bits, scales = bd.quantize_deltas(cfg, params, fine)
        extras = {n: fine[n] for n in nonlinear_names(cfg)}

        b, L = 2, 2
        shape = (cfg.n_layers, b, cfg.n_heads, cfg.max_seq_len, cfg.head_dim)
        kc = jnp.zeros(shape); vc = jnp.zeros(shape)
        kc2 = jnp.zeros(shape); vc2 = jnp.zeros(shape)
        rope = jnp.ones((b,), jnp.float32)
        lin = cfg.linear_names()
        flat_base = [params[n] for n in lin]
        flat_bits = [jnp.asarray(np.stack([bits[n]] * b)) for n in lin]
        # level axis: [real mask, all-zero padding mask]
        flat_bits_ml = [jnp.stack([x, jnp.zeros_like(x)], axis=1)
                        for x in flat_bits]
        sc = jnp.asarray(np.stack([scales] * b))               # [B, n_lin]
        sc_ml = jnp.stack([sc, jnp.zeros_like(sc)], axis=1)    # [B, L, n_lin]
        assert sc_ml.shape == (b, L, len(lin))
        flat_extras = [jnp.asarray(np.stack([np.asarray(extras[n])] * b))
                       for n in nonlinear_names(cfg)]

        pos = jnp.zeros((b,), jnp.int32)
        token = jnp.asarray([65, 66], jnp.int32)
        z1, _, _ = decode_bitdelta(cfg, flat_base, flat_bits, sc,
                                   flat_extras, kc, vc, pos, token, rope)
        z2, _, _ = decode_bitdelta_multi(cfg, flat_base, flat_bits_ml,
                                         sc_ml, flat_extras, kc2, vc2,
                                         pos, token, rope)
        np.testing.assert_allclose(np.asarray(z1), np.asarray(z2),
                                   rtol=1e-5, atol=1e-5)

    def test_naive_decode_matches_per_tenant_dense(self, params):
        """decode_naive with two different stacked models == two separate
        dense decodes."""
        cfg = TINY
        rng = np.random.default_rng(4)
        other = {n: jnp.asarray(np.asarray(w) + 0.02 *
                                rng.standard_normal(w.shape)
                                .astype(np.float32))
                 for n, w in params.items()}
        b = 2
        stacked = [jnp.stack([params[n], other[n]])
                   for n in cfg.param_names()]
        shape = (cfg.n_layers, b, cfg.n_heads, cfg.max_seq_len, cfg.head_dim)
        kc = jnp.zeros(shape); vc = jnp.zeros(shape)
        rope = jnp.ones((b,), jnp.float32)
        pos = jnp.zeros((b,), jnp.int32)
        token = jnp.asarray([65, 65], jnp.int32)
        z, _, _ = decode_naive(cfg, stacked, kc, vc, pos, token, rope)

        for i, p in enumerate((params, other)):
            shape1 = (cfg.n_layers, 1, cfg.n_heads, cfg.max_seq_len,
                      cfg.head_dim)
            z1, _, _ = decode_dense(
                cfg, flatten_params(cfg, p), jnp.zeros(shape1),
                jnp.zeros(shape1), jnp.zeros((1,), jnp.int32),
                jnp.asarray([65], jnp.int32), jnp.ones((1,), jnp.float32))
            np.testing.assert_allclose(np.asarray(z[i]), np.asarray(z1[0]),
                                       rtol=1e-4, atol=1e-4)

    def test_lora_decode_zero_factors_is_base(self, params):
        cfg = TINY
        b, r = 1, 4
        lin = cfg.linear_names()
        flat_base = [params[n] for n in lin]
        a = [jnp.zeros((b, r, cfg.linear_shape(n)[1])) for n in lin]
        bm = [jnp.zeros((b, cfg.linear_shape(n)[0], r)) for n in lin]
        extras = [params[n][None] for n in nonlinear_names(cfg)]
        shape = (cfg.n_layers, b, cfg.n_heads, cfg.max_seq_len, cfg.head_dim)
        args = (jnp.zeros(shape), jnp.zeros(shape),
                jnp.zeros((b,), jnp.int32), jnp.asarray([66], jnp.int32),
                jnp.ones((b,), jnp.float32))
        z1, _, _ = decode_lora(cfg, flat_base, a, bm, extras, *args)
        z2, _, _ = decode_dense(cfg, flatten_params(cfg, params), *args)
        np.testing.assert_allclose(np.asarray(z1), np.asarray(z2),
                                   rtol=1e-4, atol=1e-4)


class TestPrefill:
    def test_prefill_matches_decode_chain(self, params):
        """prefill(prompt) then one decode step == decoding the prompt
        token by token: same logits, same cache contents where valid."""
        cfg = TINY
        seq = list(np.random.default_rng(5).integers(0, 255, 10))
        pad = 16
        toks = np.zeros((1, pad), np.int32)
        toks[0, :len(seq)] = seq
        last, kc, vc = prefill(cfg, params, jnp.asarray(toks),
                               jnp.asarray(len(seq), jnp.int32),
                               jnp.asarray(1.0, jnp.float32))

        # decode chain
        shape = (cfg.n_layers, 1, cfg.n_heads, cfg.max_seq_len, cfg.head_dim)
        kc2 = jnp.zeros(shape); vc2 = jnp.zeros(shape)
        flat = flatten_params(cfg, params)
        for t, tok in enumerate(seq):
            z, kc2, vc2 = decode_dense(
                cfg, flat, kc2, vc2, jnp.asarray([t], jnp.int32),
                jnp.asarray([tok], jnp.int32), jnp.ones((1,), jnp.float32))
        np.testing.assert_allclose(np.asarray(last), np.asarray(z),
                                   rtol=1e-3, atol=1e-3)
        # cache slots [0, len) must agree
        np.testing.assert_allclose(
            np.asarray(kc)[:, :, :, :len(seq)],
            np.asarray(kc2)[:, :, :, :len(seq)], rtol=1e-3, atol=1e-3)

    def test_prefill_logits_match_forward(self, params):
        cfg = TINY
        seq = list(np.random.default_rng(6).integers(0, 255, 8))
        toks = np.zeros((1, 16), np.int32)
        toks[0, :len(seq)] = seq
        last, _, _ = prefill(cfg, params, jnp.asarray(toks),
                             jnp.asarray(len(seq), jnp.int32),
                             jnp.asarray(1.0, jnp.float32))
        z = forward_logits(TINY, params,
                           jnp.asarray([seq], jnp.int32))
        np.testing.assert_allclose(np.asarray(last[0]),
                                   np.asarray(z[0, -1]),
                                   rtol=1e-3, atol=1e-3)
