"""BDW container round-trips and format-level failure modes. The rust
reader (`rust/src/store/bdw.rs`) must agree with this writer bit-for-bit
— pinned on the rust side by `integration_engine.rs`."""

import struct

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.config import ModelConfig
from compile.serialize import (MAGIC, read_bdw, write_bdw, write_delta,
                               write_lora, write_model)


@pytest.fixture
def tmp_bdw(tmp_path):
    return str(tmp_path / "t.bdw")


class TestRoundtrip:
    def test_mixed_dtypes(self, tmp_bdw):
        tensors = [
            ("w", np.arange(12, dtype=np.float32).reshape(3, 4)),
            ("bits", np.array([1, 2, 255], dtype=np.uint8)),
            ("ids", np.array([[--1, 5]], dtype=np.int32)),
        ]
        write_bdw(tmp_bdw, tensors)
        out = read_bdw(tmp_bdw)
        for name, arr in tensors:
            np.testing.assert_array_equal(out[name], arr)

    def test_order_preserved(self, tmp_bdw):
        tensors = [(f"t{i}", np.zeros(i + 1, np.float32))
                   for i in range(8)]
        write_bdw(tmp_bdw, tensors)
        out = read_bdw(tmp_bdw)
        assert list(out.keys()) == [f"t{i}" for i in range(8)]

    @given(st.integers(0, 5), st.integers(1, 4))
    @settings(max_examples=10, deadline=None)
    def test_shapes_property(self, ndim_seed, scale):
        import tempfile, os
        rng = np.random.default_rng(ndim_seed * 10 + scale)
        shape = tuple(int(rng.integers(1, 4)) * scale
                      for _ in range(max(1, ndim_seed % 4)))
        arr = rng.standard_normal(shape).astype(np.float32)
        fd, p = tempfile.mkstemp(suffix=".bdw")
        os.close(fd)
        try:
            write_bdw(p, [("x", arr)])
            np.testing.assert_array_equal(read_bdw(p)["x"], arr)
        finally:
            os.remove(p)


class TestCorruption:
    def test_bitflip_detected(self, tmp_bdw):
        write_bdw(tmp_bdw, [("w", np.ones(64, np.float32))])
        buf = bytearray(open(tmp_bdw, "rb").read())
        buf[40] ^= 0x10
        open(tmp_bdw, "wb").write(bytes(buf))
        with pytest.raises(AssertionError):
            read_bdw(tmp_bdw)

    def test_magic_checked(self, tmp_bdw):
        open(tmp_bdw, "wb").write(b"NOPE" + b"\x00" * 32)
        with pytest.raises(AssertionError):
            read_bdw(tmp_bdw)

    def test_header_layout(self, tmp_bdw):
        write_bdw(tmp_bdw, [("w", np.zeros(2, np.float32))])
        buf = open(tmp_bdw, "rb").read()
        assert buf[:4] == MAGIC
        version, count = struct.unpack_from("<II", buf, 4)
        assert (version, count) == (1, 1)


class TestRoleWriters:
    def test_write_model_all_params(self, tmp_bdw):
        cfg = ModelConfig(name="t", d_model=16, n_layers=1, n_heads=2,
                          d_ff=32, max_seq_len=16)
        params = {n: np.zeros(cfg.param_shape(n), np.float32)
                  for n in cfg.param_names()}
        write_model(tmp_bdw, cfg, params)
        out = read_bdw(tmp_bdw)
        assert set(out.keys()) == set(cfg.param_names())

    def test_write_delta_layout(self, tmp_bdw):
        cfg = ModelConfig(name="t", d_model=16, n_layers=1, n_heads=2,
                          d_ff=32, max_seq_len=16)
        bits = {n: np.zeros(cfg.packed_shape(n), np.uint8)
                for n in cfg.linear_names()}
        scales = np.ones(len(cfg.linear_names()), np.float32)
        extras = {"tok_embed": np.zeros((256, 16), np.float32)}
        write_delta(tmp_bdw, cfg, [(bits, scales), (bits, scales * 0.5)],
                    extras)
        out = read_bdw(tmp_bdw)
        assert "scales.0" in out and "scales.1" in out
        assert f"bits.1.{cfg.linear_names()[0]}" in out
        assert "extra.tok_embed" in out
        np.testing.assert_allclose(out["scales.1"], 0.5)

    def test_write_lora_kernel_abi(self, tmp_bdw):
        cfg = ModelConfig(name="t", d_model=16, n_layers=1, n_heads=2,
                          d_ff=32, max_seq_len=16)
        r = 4
        factors = {}
        for n in cfg.linear_names():
            out_f, in_f = cfg.linear_shape(n)
            factors[n] = (np.zeros((r, in_f), np.float32),
                          np.zeros((out_f, r), np.float32))
        write_lora(tmp_bdw, cfg, factors, {})
        out = read_bdw(tmp_bdw)
        name = cfg.linear_names()[0]
        assert out[f"lora_a.{name}"].shape == (r, 16)
        assert out[f"lora_b.{name}"].shape == (16, r)

    def test_write_lora_rejects_mismatched_rank(self, tmp_bdw):
        cfg = ModelConfig(name="t", d_model=16, n_layers=1, n_heads=2,
                          d_ff=32, max_seq_len=16)
        factors = {}
        for n in cfg.linear_names():
            out_f, in_f = cfg.linear_shape(n)
            factors[n] = (np.zeros((4, in_f), np.float32),
                          np.zeros((out_f, 5), np.float32))   # rank clash
        with pytest.raises(AssertionError):
            write_lora(tmp_bdw, cfg, factors, {})
