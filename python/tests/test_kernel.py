"""L1 kernel correctness: Pallas kernels vs. pure-jnp oracles.

The CORE correctness signal of the build path — every serving executable
embeds these kernels, so a mismatch here is a mismatch in production
numerics. Hypothesis sweeps shapes; fixed cases pin the ABI.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.binary_gemm import (binary_gemm, hbm_bytes_per_call,
                                         vmem_footprint)
from compile.kernels.lora_gemm import lora_gemm


def _rand_case(rng, b, n, m, l):
    delta = rng.standard_normal((b, n, m)).astype(np.float32)
    bits = np.asarray(ref.pack_signs(delta))
    scale = np.abs(delta).mean(axis=(1, 2)).astype(np.float32)
    x = rng.standard_normal((b, l, m)).astype(np.float32)
    return bits, scale, x


class TestPacking:
    def test_roundtrip_exact(self):
        rng = np.random.default_rng(0)
        d = rng.standard_normal((16, 64)).astype(np.float32)
        packed = ref.pack_signs(d)
        signs = np.asarray(ref.unpack_signs(packed, 64))
        assert set(np.unique(signs)) <= {-1.0, 1.0}
        assert np.array_equal(signs > 0, np.asarray(d) > 0)

    def test_zero_maps_to_minus_one(self):
        """Paper Eq. 2: Sign(0) = -1."""
        d = np.zeros((2, 8), np.float32)
        signs = np.asarray(ref.unpack_signs(ref.pack_signs(d), 8))
        assert np.all(signs == -1.0)

    def test_np_jnp_agree(self):
        rng = np.random.default_rng(1)
        d = rng.standard_normal((8, 48)).astype(np.float32)
        assert np.array_equal(ref.pack_signs_np(d),
                              np.asarray(ref.pack_signs(d)))
        assert np.array_equal(
            ref.unpack_signs_np(ref.pack_signs_np(d), 48),
            np.asarray(ref.unpack_signs(ref.pack_signs(d), 48)))

    @given(st.integers(1, 5), st.integers(1, 7))
    @settings(max_examples=20, deadline=None)
    def test_roundtrip_property(self, rows, bytes_per_row):
        rng = np.random.default_rng(rows * 31 + bytes_per_row)
        m = bytes_per_row * 8
        d = rng.standard_normal((rows, m)).astype(np.float32)
        packed = ref.pack_signs_np(d)
        assert packed.shape == (rows, bytes_per_row)
        signs = ref.unpack_signs_np(packed, m)
        assert np.array_equal(signs, np.where(d > 0, 1.0, -1.0))


class TestBinaryGemm:
    def test_matches_ref_fixed(self):
        rng = np.random.default_rng(2)
        bits, scale, x = _rand_case(rng, 3, 128, 256, 1)
        y = binary_gemm(jnp.array(bits), jnp.array(scale), jnp.array(x))
        yref = ref.binary_gemm_ref(jnp.array(bits), jnp.array(scale),
                                   jnp.array(x))
        np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                                   rtol=1e-5, atol=1e-4)

    def test_multi_tile_grid(self):
        """Shapes larger than one block exercise the accumulation path."""
        rng = np.random.default_rng(3)
        bits, scale, x = _rand_case(rng, 2, 512, 1024, 2)
        y = binary_gemm(jnp.array(bits), jnp.array(scale), jnp.array(x),
                        block_n=128, block_m=256)
        yref = ref.binary_gemm_ref(jnp.array(bits), jnp.array(scale),
                                   jnp.array(x))
        np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                                   rtol=1e-5, atol=1e-3)

    def test_scale_zero_kills_delta(self):
        rng = np.random.default_rng(4)
        bits, _, x = _rand_case(rng, 2, 64, 64, 1)
        y = binary_gemm(jnp.array(bits), jnp.zeros(2, jnp.float32),
                        jnp.array(x))
        assert np.allclose(np.asarray(y), 0.0)

    def test_per_tenant_scales_independent(self):
        """Tenant b's output scales linearly with its own α only."""
        rng = np.random.default_rng(5)
        bits, scale, x = _rand_case(rng, 2, 64, 64, 1)
        y1 = np.asarray(binary_gemm(jnp.array(bits), jnp.array(scale),
                                    jnp.array(x)))
        scale2 = scale.copy()
        scale2[0] *= 3.0
        y2 = np.asarray(binary_gemm(jnp.array(bits), jnp.array(scale2),
                                    jnp.array(x)))
        np.testing.assert_allclose(y2[0], 3.0 * y1[0], rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(y2[1], y1[1], rtol=0, atol=0)

    @given(
        b=st.integers(1, 4),
        n_blocks=st.integers(1, 3),
        m_blocks=st.integers(1, 3),
        l=st.sampled_from([1, 2, 4]),
        bn=st.sampled_from([16, 32, 64]),
        bm=st.sampled_from([16, 32, 64]),
    )
    @settings(max_examples=25, deadline=None)
    def test_shape_sweep(self, b, n_blocks, m_blocks, l, bn, bm):
        """Hypothesis sweep over batch/tile/grid geometry."""
        n, m = bn * n_blocks, bm * m_blocks
        rng = np.random.default_rng(n * 7 + m * 3 + b)
        bits, scale, x = _rand_case(rng, b, n, m, l)
        y = binary_gemm(jnp.array(bits), jnp.array(scale), jnp.array(x),
                        block_n=bn, block_m=bm)
        yref = ref.binary_gemm_ref(jnp.array(bits), jnp.array(scale),
                                   jnp.array(x))
        np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                                   rtol=1e-4, atol=1e-3)


class TestLoraGemm:
    def test_matches_ref(self):
        rng = np.random.default_rng(6)
        b, r, n, m, l = 3, 8, 96, 128, 2
        a = rng.standard_normal((b, r, m)).astype(np.float32)
        bm_ = rng.standard_normal((b, n, r)).astype(np.float32)
        x = rng.standard_normal((b, l, m)).astype(np.float32)
        y = lora_gemm(jnp.array(a), jnp.array(bm_), jnp.array(x))
        yref = ref.lora_gemm_ref(jnp.array(a), jnp.array(bm_), jnp.array(x))
        np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                                   rtol=1e-4, atol=1e-4)

    @given(b=st.integers(1, 3), r=st.sampled_from([1, 4, 16]),
           n=st.sampled_from([16, 64]), m=st.sampled_from([16, 64]))
    @settings(max_examples=15, deadline=None)
    def test_shape_sweep(self, b, r, n, m):
        rng = np.random.default_rng(b + r + n + m)
        a = rng.standard_normal((b, r, m)).astype(np.float32)
        bm_ = rng.standard_normal((b, n, r)).astype(np.float32)
        x = rng.standard_normal((b, 1, m)).astype(np.float32)
        y = lora_gemm(jnp.array(a), jnp.array(bm_), jnp.array(x))
        yref = ref.lora_gemm_ref(jnp.array(a), jnp.array(bm_), jnp.array(x))
        np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                                   rtol=1e-4, atol=1e-4)


class TestStructuralPerf:
    """§Perf L1 structural analysis (interpret mode has no TPU wallclock:
    we bound the VMEM footprint and HBM traffic analytically)."""

    def test_default_blocks_fit_vmem(self):
        fp = vmem_footprint(256, 512)
        assert fp["peak_bytes"] < 1024 * 1024, fp
        # double-buffering headroom: 2x resident still far under 16 MB VMEM
        assert 2 * fp["resident_bytes"] < 16 * 1024 * 1024

    def test_weight_traffic_ratio_is_16x_fp16(self):
        hb = hbm_bytes_per_call(8, 4096, 4096)
        assert hb["weight_traffic_ratio"] == 16.0

    def test_packed_traffic_dominates_at_decode(self):
        hb = hbm_bytes_per_call(8, 4096, 4096, l=1)
        assert hb["packed_weight_bytes"] > hb["output_bytes"]
