"""SVD baseline (Table 1 / Fig. 2 substrate) and the synthetic-world data
generators the whole evaluation rests on."""

import numpy as np
import pytest

from compile import data as D
from compile import svd_baseline as S
from compile.config import ModelConfig


class TestSvdBaseline:
    CFG = ModelConfig(name="t", d_model=16, n_layers=1, n_heads=2,
                      d_ff=32, max_seq_len=16)

    def _pair(self):
        import jax
        from compile.model import init_params
        base = init_params(self.CFG, jax.random.PRNGKey(0))
        rng = np.random.default_rng(1)
        fine = {n: np.asarray(w) + 0.02 *
                rng.standard_normal(np.asarray(w).shape).astype(np.float32)
                for n, w in base.items()}
        return base, fine

    def test_factors_shapes(self):
        base, fine = self._pair()
        fac = S.svd_compress(self.CFG, base, fine, rank=4)
        for name in self.CFG.linear_names():
            a, b = fac[name]
            n, m = self.CFG.linear_shape(name)
            assert a.shape == (n, 4) and b.shape == (4, m)

    def test_rank_capped_at_min_dim(self):
        base, fine = self._pair()
        fac = S.svd_compress(self.CFG, base, fine, rank=9999)
        name = self.CFG.linear_names()[0]
        n, m = self.CFG.linear_shape(name)
        assert fac[name][0].shape[1] == min(n, m)

    def test_truncation_error_decreases_with_rank(self):
        base, fine = self._pair()
        name = self.CFG.linear_names()[0]
        delta = np.asarray(fine[name]) - np.asarray(base[name])
        errs = []
        for r in (1, 4, 8, 16):
            fac = S.svd_compress(self.CFG, base, fine, rank=r)
            a, b = fac[name]
            errs.append(np.linalg.norm(delta - a @ b))
        assert all(errs[i + 1] <= errs[i] + 1e-6 for i in range(3)), errs

    def test_materialize_folds_factors(self):
        base, fine = self._pair()
        fac = S.svd_compress(self.CFG, base, fine, rank=16)
        m = S.materialize_svd(self.CFG, base, fac, fine)
        name = self.CFG.linear_names()[0]
        # full-rank truncation == exact delta
        np.testing.assert_allclose(np.asarray(m[name]),
                                   np.asarray(fine[name]),
                                   rtol=1e-3, atol=1e-4)

    def test_cev_properties(self):
        rng = np.random.default_rng(2)
        d = rng.standard_normal((24, 24)).astype(np.float32)
        cev = S.cumulative_explained_variance(d)
        assert np.all(np.diff(cev) >= -1e-12)
        assert abs(cev[-1] - 1.0) < 1e-9
        # iid noise is high-rank: ~half the components for 90% variance
        assert np.searchsorted(cev, 0.9) > 24 * 0.4

    def test_low_rank_delta_is_low_rank(self):
        rng = np.random.default_rng(3)
        d = (rng.standard_normal((24, 2)) @
             rng.standard_normal((2, 24))).astype(np.float32)
        cev = S.cumulative_explained_variance(d)
        assert cev[1] > 0.99


class TestWorld:
    def test_deterministic_per_seed(self):
        w1, w2 = D.World(seed=0), D.World(seed=0)
        assert w1.color_of == w2.color_of
        assert w1.myth_of == w2.myth_of
        w3 = D.World(seed=1)
        assert w1.color_of != w3.color_of

    def test_myth_never_equals_truth(self):
        w = D.World(seed=0)
        for obj in D.OBJECTS:
            assert w.myth_of[obj] != w.color_of[obj]


class TestDatasets:
    def test_corpus_contains_facts_and_myths(self):
        w = D.World(seed=0)
        corpus = D.make_pretrain_corpus(w, n_chars=50_000)
        obj = D.OBJECTS[0]
        assert f"the {obj} is {w.color_of[obj]} ." in corpus
        assert "some say" in corpus

    def test_chat_answers_are_truthful(self):
        w = D.World(seed=0)
        docs = D.make_chat_dataset(w, n=500)
        for d in docs:
            if "what color is the" in d:
                obj = d.split("what color is the ")[1].split(" ?")[0]
                assert w.color_of[obj] in d
                assert w.myth_of[obj] not in d.split("A:")[1]

    def test_math_answers_correct(self):
        docs = D.make_math_dataset(n=300)
        for d in docs:
            q, a = d.strip().split("\nA: ")
            words = q.split()
            x, op, y = int(words[3]), words[4], int(words[5])
            want = x + y if op == "plus" else x - y
            assert int(a) == want, d

    def test_preference_pairs_disagree(self):
        w = D.World(seed=0)
        for prompt, chosen, rejected in D.make_preference_dataset(w, 100):
            assert chosen != rejected
            assert prompt.endswith("A:")


class TestEvals:
    def test_styleqa_items_well_formed(self):
        w = D.World(seed=0)
        ev = D.make_styleqa_eval(w, n=24)
        assert ev["type"] == "pair"
        for item in ev["items"]:
            assert item["correct"] != item["incorrect"]
            assert item["prompt"].endswith("is")

    def test_arith_eval_answers_correct(self):
        ev = D.make_arith_eval(n=32)
        for item in ev["items"]:
            words = item["prompt"].split()
            x, op, y = int(words[3]), words[4], int(words[5])
            want = x + y if op == "plus" else x - y
            assert item["answer"] == f" {want}"

    def test_eval_battery_complete(self, tmp_path):
        w = D.World(seed=0)
        D.write_evals(w, str(tmp_path))
        names = sorted(p.name for p in tmp_path.iterdir())
        assert names == ["arith.json", "cloze_arith.json",
                         "cloze_color.json", "cloze_food.json",
                         "cloze_home.json", "instruct.json",
                         "styleqa.json"]

    def test_tokenizer_roundtrip(self):
        s = "Q: what is 3 plus 5 ?\nA: 8\n"
        assert D.decode(D.encode(s)) == s
