"""Base-model quantizers (Table 6 substrate): RTN grids, GPTQ-lite error
propagation, QuIP-lite rotation invariants."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quant as Q


class TestRtn:
    def test_int8_error_small(self):
        rng = np.random.default_rng(0)
        w = rng.standard_normal((16, 32)).astype(np.float32)
        dq = Q.rtn_quantize_matrix(w, 8)
        rel = np.linalg.norm(w - dq) / np.linalg.norm(w)
        assert rel < 0.01, rel

    def test_bits_monotone(self):
        rng = np.random.default_rng(1)
        w = rng.standard_normal((8, 64)).astype(np.float32)
        errs = [np.linalg.norm(w - Q.rtn_quantize_matrix(w, b))
                for b in (8, 4, 2)]
        assert errs[0] < errs[1] < errs[2], errs

    def test_idempotent(self):
        """Quantizing an already-quantized matrix is a no-op."""
        rng = np.random.default_rng(2)
        w = rng.standard_normal((4, 16)).astype(np.float32)
        q1 = Q.rtn_quantize_matrix(w, 8)
        q2 = Q.rtn_quantize_matrix(q1, 8)
        np.testing.assert_allclose(q1, q2, atol=1e-6)

    @given(st.integers(0, 100))
    @settings(max_examples=15, deadline=None)
    def test_bounded_by_grid_step(self, seed):
        rng = np.random.default_rng(seed)
        w = rng.standard_normal((4, 8)).astype(np.float32)
        dq = Q.rtn_quantize_matrix(w, 8)
        # per-row error bounded by half the grid step
        step = np.abs(w).max(axis=1, keepdims=True) / 127
        assert np.all(np.abs(w - dq) <= step / 2 + 1e-7)


class TestHadamard:
    def test_orthogonal(self):
        for n in (2, 8, 32):
            h = Q._hadamard(n)
            np.testing.assert_allclose(h @ h.T, np.eye(n), atol=1e-5)

    def test_non_power_of_two_rejected(self):
        with pytest.raises(AssertionError):
            Q._hadamard(12)


class TestQuip:
    def test_rotation_roundtrip_lossless_at_high_bits(self):
        """With an (effectively) exact grid the rotate-quantize-rotate
        pipeline must return the input: isolates the rotation algebra."""
        rng = np.random.default_rng(3)
        w = rng.standard_normal((8, 16)).astype(np.float32)
        out = Q.quip_quantize_matrix(w, bits=8, seed=1)
        rel = np.linalg.norm(w - out) / np.linalg.norm(w)
        assert rel < 0.02, rel

    def test_rotation_is_isometric_on_error(self):
        """The rotation is orthogonal, so quantization error measured in
        the rotated basis equals the back-rotated error — pins the
        algebra (per-row RTN is already outlier-robust, so QuIP-lite's
        win over row-wise RTN is not asserted; the paper compares
        against absolute-grid quantizers)."""
        rng = np.random.default_rng(4)
        w = rng.standard_normal((16, 64)).astype(np.float32)
        err_quip = np.linalg.norm(w - Q.quip_quantize_matrix(w, 2, seed=5))
        err_rtn = np.linalg.norm(w - Q.rtn_quantize_matrix(w, 2))
        # same order of magnitude; both are 2-bit grids
        assert err_quip < 3.0 * err_rtn, (err_quip, err_rtn)

    def test_pads_non_pow2_dims(self):
        rng = np.random.default_rng(5)
        w = rng.standard_normal((4, 24)).astype(np.float32)   # 24 not 2^k
        out = Q.quip_quantize_matrix(w, bits=8, seed=2)
        assert out.shape == w.shape


class TestGptq:
    def _hessian(self, m, seed=0):
        rng = np.random.default_rng(seed)
        x = rng.standard_normal((64, m)).astype(np.float32)
        return x.T @ x

    def test_beats_rtn_under_hessian_metric(self):
        """GPTQ minimises ||(W-Ŵ)X||, not ||W-Ŵ||: under the calibration
        Hessian it must beat plain RTN at the same bit width."""
        rng = np.random.default_rng(6)
        m = 32
        w = rng.standard_normal((16, m)).astype(np.float32)
        h = self._hessian(m, 7)
        wq_gptq = Q.gptq_quantize_matrix(w, h, bits=3)
        wq_rtn = Q.rtn_quantize_matrix(w, 3)

        def h_err(dw):
            return float(np.trace(dw @ h @ dw.T))

        assert h_err(w - wq_gptq) < h_err(w - wq_rtn)

    def test_4bit_reasonable_direct_error(self):
        rng = np.random.default_rng(8)
        w = rng.standard_normal((8, 16)).astype(np.float32)
        wq = Q.gptq_quantize_matrix(w, self._hessian(16, 9), bits=4)
        rel = np.linalg.norm(w - wq) / np.linalg.norm(w)
        assert rel < 0.2, rel


class TestQuantizeBase:
    def test_only_linears_touched(self):
        from compile.config import ModelConfig
        from compile.model import init_params
        import jax

        cfg = ModelConfig(name="t", d_model=16, n_layers=1, n_heads=2,
                          d_ff=32, max_seq_len=16)
        params = init_params(cfg, jax.random.PRNGKey(0))
        from compile.model import nonlinear_names
        out = Q.quantize_base(cfg, params, "rtn8")
        for n in nonlinear_names(cfg):
            np.testing.assert_array_equal(out[n], np.asarray(params[n]))
        for n in cfg.linear_names():
            assert not np.array_equal(out[n], np.asarray(params[n]))
