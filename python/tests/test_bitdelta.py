"""BitDelta algorithm tests: quantization optimality, distillation
behaviour, iterative masks, and the serving-path/dense-path equivalence."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import bitdelta as bd
from compile import data as D
from compile.config import DistillConfig, ModelConfig, TrainConfig
from compile.kernels.ref import unpack_signs_np
from compile.model import (forward_logits, init_params, logits_bitdelta,
                           materialize_bitdelta, nonlinear_names)

TINY = ModelConfig(name="tiny", d_model=32, n_layers=2, n_heads=2,
                   d_ff=64, max_seq_len=64)


@pytest.fixture(scope="module")
def tiny_pair():
    """A (base, fine) pair: random init plus a small random perturbation —
    enough to exercise every code path without training."""
    base = init_params(TINY, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    fine = {}
    for n, w in base.items():
        w = np.asarray(w)
        fine[n] = jnp.asarray(w + 0.01 * rng.standard_normal(w.shape)
                              .astype(np.float32))
    return base, fine


class TestQuantize:
    def test_alpha_is_mean_abs(self, tiny_pair):
        """Eq. 4: α = mean|Δ| per matrix."""
        base, fine = tiny_pair
        bits, scales = bd.quantize_deltas(TINY, base, fine)
        for i, name in enumerate(TINY.linear_names()):
            delta = np.asarray(fine[name]) - np.asarray(base[name])
            assert np.isclose(scales[i], np.abs(delta).mean(), rtol=1e-5)

    def test_alpha_minimises_l2(self, tiny_pair):
        """Eq. 3: mean|Δ| is the L2-optimal scale for a sign matrix —
        nudging α in either direction increases the error."""
        base, fine = tiny_pair
        bits, scales = bd.quantize_deltas(TINY, base, fine)
        name = TINY.linear_names()[0]
        delta = np.asarray(fine[name]) - np.asarray(base[name])
        signs = unpack_signs_np(bits[name], delta.shape[1])

        def err(a):
            return np.sum((delta - a * signs) ** 2)

        a0 = scales[0]
        assert err(a0) < err(a0 * 1.05)
        assert err(a0) < err(a0 * 0.95)

    def test_signs_match_delta(self, tiny_pair):
        base, fine = tiny_pair
        bits, _ = bd.quantize_deltas(TINY, base, fine)
        name = TINY.linear_names()[3]
        delta = np.asarray(fine[name]) - np.asarray(base[name])
        signs = unpack_signs_np(bits[name], delta.shape[1])
        assert np.array_equal(signs > 0, delta > 0)

    @given(st.integers(0, 1000))
    @settings(max_examples=15, deadline=None)
    def test_quantize_error_bounded_property(self, seed):
        """‖Δ − Δ̂‖∞ ≤ max|Δ| + mean|Δ| always holds."""
        rng = np.random.default_rng(seed)
        d = rng.standard_normal((8, 16)).astype(np.float32)
        from compile.kernels.ref import pack_signs_np
        a = np.abs(d).mean()
        dq = a * unpack_signs_np(pack_signs_np(d), 16)
        assert np.max(np.abs(d - dq)) <= np.max(np.abs(d)) + a + 1e-6


class TestServingPathEquivalence:
    def test_materialized_equals_kernel_path(self, tiny_pair):
        """The dense dequantized model and the Pallas serving path are the
        same function (this is what lets the rust eval harness use the
        dense path for the quality tables)."""
        base, fine = tiny_pair
        bits, scales = bd.quantize_deltas(TINY, base, fine)
        extras = {n: fine[n] for n in nonlinear_names(TINY)}

        dense = materialize_bitdelta(TINY, base, bits, scales, extras)
        tokens = jnp.asarray(
            np.random.default_rng(2).integers(0, 255, (1, 24), np.int32))
        z_dense = forward_logits(TINY, dense, tokens)

        lin = TINY.linear_names()
        z_kernel = logits_bitdelta(
            TINY,
            [jnp.asarray(base[n]) for n in lin],
            [jnp.asarray(bits[n])[None] for n in lin],
            jnp.asarray(scales)[None],
            [jnp.asarray(extras[n])[None] for n in nonlinear_names(TINY)],
            tokens)
        np.testing.assert_allclose(np.asarray(z_dense),
                                   np.asarray(z_kernel),
                                   rtol=1e-3, atol=1e-3)


class TestDistillation:
    def test_distillation_reduces_logit_mse(self, tiny_pair):
        base, fine = tiny_pair
        bits, scales0 = bd.quantize_deltas(TINY, base, fine)
        world = D.World(seed=0)
        corpus = D.make_pretrain_corpus(world, n_chars=20_000)
        dcfg = DistillConfig(steps=25, n_samples=32, seq_len=32,
                             batch_size=2, lr=1e-3)
        calib = bd.calibration_batches(corpus, dcfg)

        def mse(scales):
            extras = {n: fine[n] for n in nonlinear_names(TINY)}
            dense = materialize_bitdelta(TINY, base, bits, scales, extras)
            toks = jnp.asarray(calib[:4, :32].astype(np.int32))
            zf = forward_logits(TINY, fine, toks)
            zb = forward_logits(TINY, dense, toks)
            return float(jnp.mean((zf - zb) ** 2))

        before = mse(scales0)
        scales1 = bd.distill_scales(TINY, base, fine, bits, scales0,
                                    calib, dcfg, tag="test-distill")
        after = mse(scales1)
        assert after < before, (before, after)

    def test_distilled_scales_stay_finite_positive_mix(self, tiny_pair):
        base, fine = tiny_pair
        bits, scales0 = bd.quantize_deltas(TINY, base, fine)
        assert np.all(np.isfinite(scales0)) and np.all(scales0 > 0)


class TestIterative:
    def test_residual_shrinks_monotonically(self, tiny_pair):
        """Each extra 1-bit mask reduces the reconstruction error (the
        mechanism behind Fig. 3's approach to the fine-tune)."""
        base, fine = tiny_pair
        masks = bd.iterative_bitdelta(TINY, base, fine, 5)
        name = TINY.linear_names()[0]
        delta = np.asarray(fine[name]) - np.asarray(base[name])
        _, m = TINY.linear_shape(name)
        i = TINY.linear_names().index(name)

        recon = np.zeros_like(delta)
        errs = []
        for bits, scales in masks:
            recon = recon + scales[i] * unpack_signs_np(bits[name], m)
            errs.append(float(np.sum((delta - recon) ** 2)))
        assert all(errs[j + 1] < errs[j] for j in range(len(errs) - 1)), errs

    def test_scales_decay_geometrically(self, tiny_pair):
        base, fine = tiny_pair
        masks = bd.iterative_bitdelta(TINY, base, fine, 4)
        s = [m[1][0] for m in masks]
        assert all(s[j + 1] < s[j] for j in range(len(s) - 1)), s

    def test_apply_masks_level1_equals_materialize(self, tiny_pair):
        base, fine = tiny_pair
        bits, scales = bd.quantize_deltas(TINY, base, fine)
        masks = bd.iterative_bitdelta(TINY, base, fine, 1)
        extras = {n: fine[n] for n in nonlinear_names(TINY)}
        m1 = bd.apply_masks(TINY, base, masks, fine)
        m2 = materialize_bitdelta(TINY, base, bits, scales, extras)
        for n in TINY.linear_names():
            np.testing.assert_allclose(np.asarray(m1[n]), np.asarray(m2[n]),
                                       rtol=1e-5, atol=1e-6)


class TestSizeAccounting:
    def test_compression_factor_exceeds_paper_threshold(self):
        """Table 5: >10x for Llama-scale dims. Verify with the real
        Llama-2-7B architecture numbers."""
        llama7b = ModelConfig(name="llama7b", vocab_size=32000,
                              d_model=4096, n_layers=32, n_heads=32,
                              d_ff=11008, max_seq_len=4096)
        info = bd.delta_size_bytes(llama7b, fp_bytes=2)   # fp16 like paper
        assert info["compression_factor"] > 10.0, info

    def test_our_config_factor(self):
        info = bd.delta_size_bytes(TINY)
        # tiny vocab-heavy models compress less; factor must still be > 1
        assert info["compression_factor"] > 1.0
